//! Persistent, event-driven propagation engine.
//!
//! One [`PropagationEngine`] instance lives for the whole search and
//! owns everything the inner loop touches: the domains, the trail, the
//! two-tier propagation queue, the typed-event scratch buffer, the
//! persistent objective-bound propagator, and per-`Cumulative`
//! incremental state. It replaces the three copies of queue/enqueue
//! logic the search previously carried (root fixpoint, left-branch
//! fixpoint, right-branch re-propagation) with a single implementation.
//!
//! Design (notify-style propagation, after the watch-list engines in
//! SNIPPETS.md):
//!
//! * **Typed events.** Every bound tightening posts a [`DomainEvent`]
//!   carrying [`event::LB`] / [`event::UB`] (plus [`event::FIX`] when
//!   the domain collapses). Watch lists store an event mask per
//!   (propagator, variable) — see [`Propagator::watch_masks`] — so
//!   `LeOffset` and `Cover` wake only on the bound they actually read.
//!   Skipped wakeups are counted in `SearchStats::wakeups_skipped`.
//! * **Two-tier queue.** Cheap propagators (`LinearLe`, `LeOffset`,
//!   `Cover`, `AllDifferent`, the objective) drain to fixpoint first;
//!   `Cumulative` runs only once the cheap tier is empty, so it sees
//!   settled bounds instead of being re-woken once per small change.
//! * **Incremental `Cumulative`.** The timetable profile of compulsory
//!   parts is maintained structurally ([`ProfileMode`]): by default a
//!   sparse lazy **segment tree** (`cp::segtree`) giving O(log H) part
//!   moves, point loads, overload checks and first-overload queries —
//!   the large-graph scaling lever — with the PR-2 diff-map + flattened
//!   step profile retained behind `--profile linear` as the A/B
//!   baseline and fuzz oracle. Either way the profile is updated per
//!   changed interval from events and re-synchronised on backtrack
//!   (counted in `SearchStats::cum_resyncs`) instead of being rebuilt
//!   from all items on every invocation, and filtering re-examines only
//!   items whose variables changed, unless the profile itself moved.
//! * **CSR hot paths.** The per-variable watcher lists, the
//!   var → cumulative-item index and the learned search's
//!   var → branch-position map are flattened into [`Csr`] arenas: the
//!   event-drain and undo loops walk contiguous slices instead of
//!   chasing one heap `Vec` per variable — the difference is measurable
//!   once models reach the `L1`–`L4` tier (n ≥ 1000).
//! * **Minimal backtrack re-enqueue.** Undoing a frame restores a state
//!   that was a propagation fixpoint, so only the propagators watching
//!   undone variables plus the objective (whose bound may have
//!   tightened since the subtree was entered) are re-enqueued.
//! * **Zero steady-state allocation.** Every buffer the engine and the
//!   search touch per node is pooled in [`SolveCtx`] and stolen/
//!   recycled around each solve, so re-solves on a warmed context make
//!   no heap allocation (asserted exactly by the counting-allocator
//!   test `reused_ctx_steady_state_is_allocation_free`). The audit of
//!   the remaining `clone()`/`vec![]`/`Vec::new` sites under `cp/`
//!   found these *deliberate* survivors, all off the chronological
//!   steady-state path: learned-search no-good literal vectors (owned
//!   by [`NoGoodDb`] across the solve, so they cannot be pooled) and
//!   the learned activity/heap/database built per learned solve; the
//!   linear profile's `BTreeMap` (frees nodes on `clear` — it is the
//!   A/B oracle, not the default); profile reconstruction on a
//!   mode-change reset (one allocation per A/B flip); model/presolve
//!   construction (once per outer solve, outside the kernel); and the
//!   `cfg(test)`/`prop-audit` explanation-replay harness.
//!
//! A `naive` mode reproduces the pre-engine reference semantics — wake
//! every watcher on any event, one queue, `Cumulative` rebuilt from
//! scratch, re-enqueue everything on backtrack — and exists solely so
//! tests can assert the engines agree (`rust/tests/property_tests.rs`).
//! Exactness never depends on filtering either way: every emitted
//! solution is verified against all constraints before it is reported.

use super::disjunctive::prop_disjunctive;
use super::domain::{event, DomStore, DomainEvent, Lit, VarId};
use super::learn::NoGoodDb;
use super::propagators::{
    edge_finding_filter_item, explain_profile_at, prop_linear_le, timetable_filter_item,
    Conflict, Ctx, CumItem, ExplState, ProfileView, Propagator, TrailEntry,
    REASON_DECISION, REASON_PROP,
};
use super::search::{SearchScratch, SearchStats, SearchStrategy};
use super::segtree::SegTreeProfile;
use super::Model;
use crate::util::{Csr, Incumbent};
use std::collections::BTreeMap;
use std::mem;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// Cadence (in propagator runs, power of two) at which `fixpoint`
/// publishes a heartbeat and polls the cancellation flag.
const PULSE_EVERY: u32 = 64;
/// Cadence (in propagator runs, power of two, multiple of
/// `PULSE_EVERY`) at which `fixpoint` reads the monotonic clock and
/// compares it against the hard stop.
const CLOCK_EVERY: u32 = 1024;

/// Which data structure the incremental `Cumulative` state maintains
/// for its compulsory-part timetable profile. Both are exact and
/// answer every filter query with identical values (asserted by
/// `prop_segtree_profile_matches_linear`); they differ only in cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProfileMode {
    /// Diff map + flattened `(time, load)` step vector: O(K) re-flatten
    /// whenever any part moves (K = number of breakpoints, which grows
    /// with the instance). The PR-2 structure, retained as the fuzz
    /// oracle and the `--profile linear` A/B baseline.
    Linear,
    /// Sparse lazy range-add / max segment tree: O(log H) per part
    /// move and per query, no re-flatten — the large-graph default.
    SegTree,
}

impl ProfileMode {
    /// Parse a CLI profile name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "linear" => Some(ProfileMode::Linear),
            "segtree" => Some(ProfileMode::SegTree),
            _ => None,
        }
    }

    /// Stable display name (`bench large-json` records it per run).
    pub fn name(&self) -> &'static str {
        match self {
            ProfileMode::Linear => "linear",
            ProfileMode::SegTree => "segtree",
        }
    }
}

/// How strongly the engine filters the cumulative memory constraint
/// (`--filtering`). Both modes are exact — filtering strength never
/// changes the reported status or optimum, only the size of the search
/// tree (asserted by `prop_edge_finding_preserves_optimum`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FilteringMode {
    /// Plain timetable filtering over compulsory parts — the default,
    /// and the reference semantics the naive engine mirrors (the
    /// engine-vs-naive equivalence tests walk identical trees only in
    /// this mode).
    Timetable,
    /// Timetable plus timetable edge-finding: energy-based start/end
    /// filtering over the compulsory-part profile (see
    /// `propagators::edge_finding_filter_item`). Strictly stronger —
    /// runs only on the engine's incremental path.
    EdgeFinding,
}

impl FilteringMode {
    /// Parse a CLI filtering name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "timetable" => Some(FilteringMode::Timetable),
            "edge-finding" => Some(FilteringMode::EdgeFinding),
            _ => None,
        }
    }

    /// Stable display name (`bench large-json` records it per run).
    pub fn name(&self) -> &'static str {
        match self {
            FilteringMode::Timetable => "timetable",
            FilteringMode::EdgeFinding => "edge-finding",
        }
    }
}

/// The profile representation behind one `Cumulative`'s incremental
/// state (selected by [`ProfileMode`]).
enum ProfileData {
    /// Sparse derivative (time → net demand change) plus the step
    /// profile flattened from it lazily, with its max load.
    Linear {
        diff: BTreeMap<i64, i64>,
        profile: Vec<(i64, i64)>,
        max_load: i64,
        dirty: bool,
    },
    /// Sparse lazy segment tree (see `cp::segtree`).
    Seg(SegTreeProfile),
}

impl ProfileData {
    /// Add (`d > 0`) or remove (`d < 0`) one compulsory part
    /// `[a, b]` × `|d|` from the profile.
    fn apply(&mut self, a: i64, b: i64, d: i64) {
        match self {
            ProfileData::Linear { diff, dirty, .. } => {
                add_diff(diff, a, d);
                add_diff(diff, b + 1, -d);
                *dirty = true;
            }
            ProfileData::Seg(t) => t.range_add(a, b + 1, d),
        }
    }
}

/// Incremental state for one `Cumulative` propagator: the registered
/// compulsory part per item plus the profile they induce.
pub(crate) struct CumState {
    /// The propagator's items (copied so resyncs never borrow the
    /// model) and capacity.
    items: Vec<CumItem>,
    cap: i64,
    /// Registered compulsory part `[ms, me]` per item (`None` = no
    /// mandatory contribution; never registered for zero-demand items,
    /// which cannot change any load). Invariant: the profile data
    /// always equals the sum of the registered parts' contributions.
    reg: Vec<Option<(i64, i64)>>,
    /// Number of registered parts — `0` means the profile is
    /// everywhere zero and the pass can skip filtering entirely,
    /// matching the reference propagator's empty-profile early return.
    nparts: usize,
    /// The timetable profile ([`ProfileMode`] selects the structure).
    data: ProfileData,
    /// Bumped whenever a registered part (hence the profile) changes.
    version: u64,
    /// `version` at the last completed filter pass; a mismatch forces a
    /// full-item pass, a match allows filtering dirty items only.
    last_filter_version: u64,
    /// Items whose variables changed since the last completed pass.
    dirty: Vec<u32>,
    dirty_flag: Vec<bool>,
}

impl CumState {
    /// Refill a pooled state in place for a (possibly different)
    /// cumulative propagator, keeping every buffer's capacity. The
    /// profile structure is reset when its mode matches the requested
    /// one and rebuilt otherwise (mode changes between solves are rare
    /// — an A/B flip — and pay one allocation).
    fn reset(&mut self, items: &[CumItem], cap: i64, profile: ProfileMode, tlo: i64, thi: i64) {
        self.items.clear();
        self.items.extend_from_slice(items);
        self.cap = cap;
        self.reg.clear();
        self.reg.resize(items.len(), None);
        self.nparts = 0;
        self.version = 0;
        self.last_filter_version = u64::MAX;
        self.dirty.clear();
        self.dirty_flag.clear();
        self.dirty_flag.resize(items.len(), false);
        match (&mut self.data, profile) {
            (ProfileData::Linear { diff, profile, max_load, dirty }, ProfileMode::Linear) => {
                // `BTreeMap::clear` frees its nodes, so the linear
                // profile cannot be steady-state allocation-free — it
                // is the A/B baseline / fuzz oracle; the segment-tree
                // default resets without touching the heap
                diff.clear();
                profile.clear();
                *max_load = 0;
                *dirty = true;
            }
            (ProfileData::Seg(t), ProfileMode::SegTree) => t.reset(tlo, thi + 2),
            (d, ProfileMode::Linear) => {
                *d = ProfileData::Linear {
                    diff: BTreeMap::new(),
                    profile: Vec::new(),
                    max_load: 0,
                    dirty: true,
                };
            }
            (d, ProfileMode::SegTree) => *d = ProfileData::Seg(SegTreeProfile::new(tlo, thi + 2)),
        }
    }

    /// Fresh state with empty buffers (pool growth path; `reset` fills
    /// it immediately after).
    fn empty(profile: ProfileMode) -> Self {
        CumState {
            items: Vec::new(),
            cap: 0,
            reg: Vec::new(),
            nparts: 0,
            data: match profile {
                ProfileMode::Linear => ProfileData::Linear {
                    diff: BTreeMap::new(),
                    profile: Vec::new(),
                    max_load: 0,
                    dirty: true,
                },
                ProfileMode::SegTree => ProfileData::Seg(SegTreeProfile::new(0, 1)),
            },
            version: 0,
            last_filter_version: u64::MAX,
            dirty: Vec::new(),
            dirty_flag: Vec::new(),
        }
    }
}

/// Reusable solve-context arena: every buffer a [`PropagationEngine`]
/// and the search layer allocate, pooled across engine constructions.
///
/// Constructing an engine used to allocate the domains, trail,
/// explanation tables, queues, watcher arenas and per-`Cumulative`
/// incremental state from scratch — a repeat cost paid once per LNS
/// window re-solve (hundreds of times per solve on paper-scale runs).
/// A `SolveCtx` is created once per [`crate::MoccasinSolver`] solve and
/// threaded through every engine construction: [`PropagationEngine::new`]
/// *steals* the buffers (capacity intact), resets their lengths for the
/// model at hand, and [`PropagationEngine::recycle`] hands them back
/// when the search returns. Steady-state window re-solves on a reused
/// context perform no heap allocation at all (asserted by the
/// counting-allocator regression test).
///
/// `Default` is the only constructor: an empty context is valid for any
/// model and simply grows to fit on first use.
#[derive(Default)]
pub struct SolveCtx {
    pub(crate) doms: DomStore,
    pub(crate) trail: Vec<TrailEntry>,
    pub(crate) expl: ExplState,
    pub(crate) level_marks: Vec<u32>,
    pub(crate) ng: NoGoodDb,
    pub(crate) events: Vec<DomainEvent>,
    pub(crate) queue_fast: Vec<u32>,
    pub(crate) queue_slow: Vec<u32>,
    pub(crate) in_queue: Vec<bool>,
    pub(crate) tier_slow: Vec<bool>,
    pub(crate) watch: Csr<(u32, u8)>,
    pub(crate) cum_of_prop: Vec<Option<u32>>,
    /// Pooled per-`Cumulative` incremental states, reused in order.
    pub(crate) cum_pool: Vec<CumState>,
    pub(crate) cum_index: Csr<(u32, u32)>,
    /// Nested-row scratch for building `cum_index` (rows are cleared,
    /// not dropped, so their capacity survives the rebuild).
    pub(crate) cum_rows: Vec<Vec<(u32, u32)>>,
    pub(crate) obj_terms: Vec<(i64, VarId)>,
    pub(crate) obj_mask: Vec<u8>,
    /// Search-layer scratch (branch heap, activities, analysis buffers,
    /// solution pool) — see `cp::search`.
    pub(crate) search: SearchScratch,
}

impl SolveCtx {
    /// Return a solution vector previously handed out in a
    /// `SearchResult::best` produced with this context, so the next
    /// solve's incumbent storage comes from the pool instead of the
    /// heap. Optional — dropping the vector is always sound, it just
    /// costs the next solve one allocation.
    pub fn recycle_solution(&mut self, v: Vec<i64>) {
        self.search.recycle_solution(v);
    }
}

/// The persistent propagation engine (see module docs).
pub(crate) struct PropagationEngine {
    /// Trailed domain bounds in SoA layout (packed lo/hi index arrays
    /// over shared value representations — see `domain::DomStore`),
    /// indexed by [`VarId`].
    pub doms: DomStore,
    /// Trailed bound changes — undone in reverse order on backtrack.
    /// Each entry carries the literal it established plus (when
    /// explanations are on) the provenance conflict analysis needs.
    pub trail: Vec<TrailEntry>,
    /// Explanation state: literal arena, scratch/conflict buffers,
    /// per-variable latest-entry chain (see `propagators::ExplState`).
    pub(crate) expl: ExplState,
    /// Trail length immediately before each decision — `level_marks[i]`
    /// opens decision level `i + 1` (learned search only).
    pub(crate) level_marks: Vec<u32>,
    /// Learned-no-good database: watched bound literals, activity, and
    /// its own propagation queue drained with the cheap tier.
    pub(crate) ng: NoGoodDb,
    /// Search statistics (the search layer also counts nodes/conflicts
    /// here so everything lives in one place).
    pub stats: SearchStats,
    /// Typed-event scratch buffer shared by every propagation pass.
    events: Vec<DomainEvent>,
    /// Cheap tier: everything but `Cumulative`; drained first.
    queue_fast: Vec<u32>,
    /// Expensive tier: `Cumulative` propagators.
    queue_slow: Vec<u32>,
    in_queue: Vec<bool>,
    tier_slow: Vec<bool>,
    /// var → (propagator id, event mask) watcher pairs, flattened into
    /// a CSR arena: the event-drain and undo loops walk one contiguous
    /// slice per variable instead of chasing a `Vec` per variable
    /// (built once from [`Model::watches`] at engine construction).
    watch: Csr<(u32, u8)>,
    /// prop id → index into `cum_states` for `Cumulative` propagators.
    cum_of_prop: Vec<Option<u32>>,
    /// The context's `CumState` pool; entries `0..` this model's
    /// cumulative count are live, any extras from a previous larger
    /// model ride along inert (their capacity is the point).
    cum_states: Vec<CumState>,
    /// var → (cum state index, item index) pairs needing resync when
    /// the variable's bounds change (forward or on undo) — CSR, same
    /// rationale as `watch`.
    cum_index: Csr<(u32, u32)>,
    /// Row scratch `cum_index` was built from, carried only so
    /// `recycle` can hand it back to the context.
    cum_rows: Vec<Vec<(u32, u32)>>,
    /// Persistent objective-bound propagator: Σ obj_terms ≤ obj_rhs,
    /// with `obj_rhs` tightened in place (never rebuilt per pass).
    obj_terms: Vec<(i64, VarId)>,
    obj_rhs: i64,
    /// var → event mask that can tighten the objective's slack.
    obj_mask: Vec<u8>,
    obj_pid: u32,
    has_obj: bool,
    /// Reference mode: wake everything on any event, single queue,
    /// from-scratch `Cumulative`, re-enqueue all on backtrack.
    naive: bool,
    /// Cumulative filtering strength (`SearchStrategy::filtering`).
    filtering: FilteringMode,
    /// Whether `Disjunctive` propagators run (`SearchStrategy::
    /// disjunctive`); when off they are intercepted as no-ops in both
    /// engine and naive mode, so one built model serves both sides of
    /// the A/B.
    disjunctive: bool,
    /// Watchdog plumbing: heartbeat/cancellation handle observed
    /// *inside* `fixpoint` at a coarse cadence, so a solve stuck in a
    /// single propagation pass is still cancellable (the search loops'
    /// deadline polls only run between nodes). The engine publishes a
    /// progress epoch ([`Incumbent::beat`]) and aborts when the shared
    /// incumbent is cancelled.
    pulse: Option<Arc<Incumbent>>,
    /// Absolute wall-clock stop checked (even more coarsely) inside
    /// `fixpoint`, covering solves that have no shared incumbent.
    hard_stop: Option<Instant>,
    /// Set when `fixpoint` bailed out early on cancellation or the hard
    /// stop: domains are mid-propagation (sound — only narrowed), no
    /// conflict was raised, and the search loop must treat the node as
    /// a timeout rather than keep branching.
    pub(crate) aborted: bool,
    /// Coarse tick counter driving the in-fixpoint watchdog checks.
    ticks: u32,
    /// Explanation-soundness audits performed so far (test / prop-audit
    /// builds only): every explained pruning and conflict is replayed
    /// against a fresh naive propagation until the budget is spent.
    #[cfg(any(test, feature = "prop-audit"))]
    audits_done: u64,
}

/// Compulsory part of an item under `doms`: `[max(start), min(end)]`
/// when the item is certainly active and the window is nonempty.
fn compulsory_part(doms: &DomStore, it: &CumItem) -> Option<(i64, i64)> {
    if doms.min(it.active) != 1 {
        return None;
    }
    let ms = doms.max(it.start);
    let me = doms.min(it.end);
    if ms <= me {
        Some((ms, me))
    } else {
        None
    }
}

/// Add `d` to the diff map at `t`, dropping zero entries.
fn add_diff(diff: &mut BTreeMap<i64, i64>, t: i64, d: i64) {
    if d == 0 {
        return;
    }
    use std::collections::btree_map::Entry;
    match diff.entry(t) {
        Entry::Vacant(e) => {
            e.insert(d);
        }
        Entry::Occupied(mut e) => {
            *e.get_mut() += d;
            if *e.get() == 0 {
                e.remove();
            }
        }
    }
}

/// Run one `Cumulative` pass off the incremental state: bring the
/// profile up to date (linear mode re-flattens its diff map; the
/// segment tree is always current), conflict-check the max load, then
/// filter either every item (profile moved) or only dirty ones.
fn cumulative_filter(
    cs: &mut CumState,
    filtering: FilteringMode,
    ctx: &mut Ctx,
    stats: &mut SearchStats,
) -> Result<(), Conflict> {
    if let ProfileData::Linear { diff, profile, max_load, dirty } = &mut cs.data {
        if *dirty {
            profile.clear();
            *max_load = 0;
            let mut load = 0i64;
            for (&t, &d) in diff.iter() {
                load += d;
                profile.push((t, load));
                if load > *max_load {
                    *max_load = load;
                }
            }
            *dirty = false;
            stats.cum_rebuilds += 1;
        }
    }
    // Empty profile: no mandatory part anywhere — match the reference
    // propagator's early return (it filters nothing in this case).
    if cs.nparts > 0 {
        let max_load = match &cs.data {
            ProfileData::Linear { max_load, .. } => *max_load,
            ProfileData::Seg(t) => t.max(),
        };
        if max_load > cs.cap {
            if ctx.explaining() {
                // explain the overload at the earliest point carrying
                // the max load (current-domain compulsory parts cover
                // at least what the cached profile registered there);
                // both structures report the same witness breakpoint
                let t = match &cs.data {
                    ProfileData::Linear { profile, .. } => profile
                        .iter()
                        .find(|&&(_, l)| l == max_load)
                        .map(|&(t, _)| t)
                        .unwrap_or(profile[0].0),
                    ProfileData::Seg(t) => t.peak_time(),
                };
                ctx.begin_expl();
                explain_profile_at(&cs.items, t, usize::MAX, ctx);
            }
            return ctx.fail();
        }
        let view = match &cs.data {
            ProfileData::Linear { profile, .. } => ProfileView::Steps(&profile[..]),
            ProfileData::Seg(t) => ProfileView::Tree(t),
        };
        let ef = filtering == FilteringMode::EdgeFinding;
        if cs.last_filter_version != cs.version {
            for ii in 0..cs.items.len() {
                timetable_filter_item(&cs.items, ii, cs.cap, &view, ctx)?;
                if ef {
                    edge_finding_filter_item(
                        &cs.items,
                        ii,
                        cs.cap,
                        &view,
                        ctx,
                        &mut stats.ef_prunes,
                    )?;
                }
            }
        } else {
            for &ii in &cs.dirty {
                timetable_filter_item(&cs.items, ii as usize, cs.cap, &view, ctx)?;
                if ef {
                    edge_finding_filter_item(
                        &cs.items,
                        ii as usize,
                        cs.cap,
                        &view,
                        ctx,
                        &mut stats.ef_prunes,
                    )?;
                }
            }
        }
    }
    // completed pass: mark clean (on conflict the dirty set survives,
    // which is safe — re-filtering is always sound)
    cs.last_filter_version = cs.version;
    for &ii in &cs.dirty {
        cs.dirty_flag[ii as usize] = false;
    }
    cs.dirty.clear();
    Ok(())
}

impl PropagationEngine {
    /// Build an engine over `model` minimizing `objective` (empty =
    /// satisfaction), stealing every buffer from `ctx` — lengths are
    /// reset for this model, capacity is kept, and nothing is
    /// reallocated when the context has already seen a model at least
    /// this large (the LNS window-re-solve steady state). Give the
    /// buffers back with [`PropagationEngine::recycle`] when the search
    /// returns.
    ///
    /// `naive` selects the reference re-enqueue-everything
    /// semantics; `explain` turns on explanation recording (the learned
    /// search's requirement — chronological search passes `false` and
    /// pays nothing); `strategy` carries the kernel-level knobs the
    /// engine reads: the incremental `Cumulative` timetable structure
    /// ([`ProfileMode`]), the cumulative filtering strength
    /// ([`FilteringMode`]) and the disjunctive on/off gate.
    pub fn new(
        model: &Model,
        objective: &[(i64, VarId)],
        naive: bool,
        explain: bool,
        strategy: &SearchStrategy,
        ctx: &mut SolveCtx,
    ) -> Self {
        let profile = strategy.profile;
        let nvars = model.domains.len();
        let nprops = model.props.len();
        let mut doms = mem::take(&mut ctx.doms);
        doms.load_from(&model.domains);
        let has_obj = !objective.is_empty();
        let mut obj_mask = mem::take(&mut ctx.obj_mask);
        obj_mask.clear();
        obj_mask.resize(nvars, 0u8);
        for &(c, v) in objective {
            if c > 0 {
                obj_mask[v.0 as usize] |= event::LB;
            } else if c < 0 {
                obj_mask[v.0 as usize] |= event::UB;
            }
        }
        let mut obj_terms = mem::take(&mut ctx.obj_terms);
        obj_terms.clear();
        obj_terms.extend_from_slice(objective);
        let mut trail = mem::take(&mut ctx.trail);
        trail.clear();
        let mut expl = mem::take(&mut ctx.expl);
        expl.reset(nvars, explain);
        let mut level_marks = mem::take(&mut ctx.level_marks);
        level_marks.clear();
        let mut ng = mem::take(&mut ctx.ng);
        ng.reset(nvars);
        let mut events = mem::take(&mut ctx.events);
        events.clear();
        let mut queue_fast = mem::take(&mut ctx.queue_fast);
        queue_fast.clear();
        let mut queue_slow = mem::take(&mut ctx.queue_slow);
        queue_slow.clear();
        let mut in_queue = mem::take(&mut ctx.in_queue);
        in_queue.clear();
        in_queue.resize(nprops + 1, false);
        let mut tier_slow = mem::take(&mut ctx.tier_slow);
        tier_slow.clear();
        tier_slow.resize(nprops + 1, false);
        let mut cum_of_prop = mem::take(&mut ctx.cum_of_prop);
        cum_of_prop.clear();
        cum_of_prop.resize(nprops + 1, None);
        let mut cum_states = mem::take(&mut ctx.cum_pool);
        let mut cum_rows = mem::take(&mut ctx.cum_rows);
        for r in cum_rows.iter_mut() {
            r.clear();
        }
        if cum_rows.len() < nvars {
            cum_rows.resize_with(nvars, Vec::new);
        }
        // stamp the detection result into this run's stats so portfolio
        // merges and `solve --verbose` see it on every solve path
        let mut stats = SearchStats::default();
        for p in model.props.iter() {
            if let Propagator::Disjunctive { items } = p {
                let h = items.len() as u64;
                stats.disj_pairs_detected += h * (h - 1) / 2;
            }
        }
        let mut used_cums = 0usize;
        for (pid, p) in model.props.iter().enumerate() {
            let Propagator::Cumulative { items, cap } = p else {
                continue;
            };
            tier_slow[pid] = true;
            let ci = used_cums as u32;
            cum_of_prop[pid] = Some(ci);
            // segment-tree coordinate range: every part boundary is a
            // value of some start/end domain, so the initial domain
            // extremes bound the axis for the whole solve
            let (mut tlo, mut thi) = (i64::MAX, i64::MIN);
            for it in items.iter() {
                tlo = tlo.min(doms.min(it.start));
                thi = thi.max(doms.max(it.end));
            }
            if tlo > thi {
                (tlo, thi) = (0, 0); // no items: degenerate axis
            }
            if used_cums == cum_states.len() {
                cum_states.push(CumState::empty(profile));
            }
            let cs = &mut cum_states[used_cums];
            used_cums += 1;
            cs.reset(items, *cap, profile, tlo, thi);
            for (ii, it) in items.iter().enumerate() {
                if it.demand == 0 {
                    // cannot change any load: never registered, never
                    // resynced, never dirty-marked (filtering is a
                    // no-op for zero demand) — so not indexed either,
                    // sparing the drain/undo paths a wasted
                    // compulsory-part recomputation per event
                    continue;
                }
                for v in [it.active, it.start, it.end] {
                    cum_rows[v.0 as usize].push((ci, ii as u32));
                }
                let part = compulsory_part(&doms, it);
                if let Some((a, b)) = part {
                    cs.data.apply(a, b, it.demand);
                    cs.nparts += 1;
                }
                cs.reg[ii] = part;
            }
        }
        // flatten the model's per-var watcher rows into the CSR arena
        // the hot drain/undo loops walk, reusing the context's arenas
        let mut watch = mem::take(&mut ctx.watch);
        watch.rebuild_from_rows(&model.watches);
        let mut cum_index = mem::take(&mut ctx.cum_index);
        cum_index.rebuild_from_rows(&cum_rows[..nvars]);
        PropagationEngine {
            doms,
            trail,
            expl,
            level_marks,
            ng,
            stats,
            events,
            queue_fast,
            queue_slow,
            in_queue,
            tier_slow,
            watch,
            cum_of_prop,
            cum_states,
            cum_index,
            cum_rows,
            obj_terms,
            obj_rhs: i64::MAX / 4,
            obj_mask,
            obj_pid: nprops as u32,
            has_obj,
            naive,
            filtering: strategy.filtering,
            disjunctive: strategy.disjunctive,
            pulse: None,
            hard_stop: None,
            aborted: false,
            ticks: 0,
            #[cfg(any(test, feature = "prop-audit"))]
            audits_done: 0,
        }
    }

    /// Hand every pooled buffer back to `ctx` for the next engine
    /// construction (capacities intact). The engine's terminal stats
    /// stay with the caller — read them before recycling.
    pub fn recycle(self, ctx: &mut SolveCtx) {
        ctx.doms = self.doms;
        ctx.trail = self.trail;
        ctx.expl = self.expl;
        ctx.level_marks = self.level_marks;
        ctx.ng = self.ng;
        ctx.events = self.events;
        ctx.queue_fast = self.queue_fast;
        ctx.queue_slow = self.queue_slow;
        ctx.in_queue = self.in_queue;
        ctx.tier_slow = self.tier_slow;
        ctx.watch = self.watch;
        ctx.cum_of_prop = self.cum_of_prop;
        ctx.cum_pool = self.cum_states;
        ctx.cum_index = self.cum_index;
        ctx.cum_rows = self.cum_rows;
        ctx.obj_terms = self.obj_terms;
        ctx.obj_mask = self.obj_mask;
    }

    /// Attach the watchdog channel: `pulse` receives heartbeat epochs
    /// and supplies the cancellation flag; `hard_stop` is the absolute
    /// wall-clock limit. Both are polled inside `fixpoint` at a coarse
    /// cadence (every `PULSE_EVERY`/`CLOCK_EVERY` propagator runs).
    pub fn set_watchdog(&mut self, pulse: Option<Arc<Incumbent>>, hard_stop: Option<Instant>) {
        self.pulse = pulse;
        self.hard_stop = hard_stop;
    }

    /// In-fixpoint watchdog poll: publish a heartbeat and check for
    /// cancellation every `PULSE_EVERY` propagator runs, and compare
    /// the monotonic clock against the hard stop every `CLOCK_EVERY`.
    /// Returns `true` when the current `fixpoint` call must abort.
    #[inline]
    fn watchdog_tick(&mut self) -> bool {
        self.ticks = self.ticks.wrapping_add(1);
        if self.ticks & (PULSE_EVERY - 1) != 0 {
            return false;
        }
        // `should_stop` folds in both cancellation (watchdog / proof
        // race) and serving-tier preemption, so a `Preempt` control
        // signal interrupts a solve wedged *inside* one fixpoint at the
        // same cadence a watchdog kill would.
        if let Some(p) = &self.pulse {
            p.beat();
            if p.should_stop() {
                return true;
            }
        }
        if self.ticks & (CLOCK_EVERY - 1) == 0 {
            if let Some(h) = self.hard_stop {
                if Instant::now() >= h {
                    return true;
                }
            }
        }
        false
    }

    /// Tighten the objective bound in place; re-enqueues the objective
    /// propagator when the bound strictly improved.
    pub fn tighten_obj_bound(&mut self, rhs: i64) {
        if self.has_obj && rhs < self.obj_rhs {
            self.obj_rhs = rhs;
            self.enqueue(self.obj_pid);
        }
    }

    fn enqueue(&mut self, pid: u32) {
        let pi = pid as usize;
        if !self.in_queue[pi] {
            self.in_queue[pi] = true;
            if !self.naive && self.tier_slow[pi] {
                self.queue_slow.push(pid);
            } else {
                self.queue_fast.push(pid);
            }
        }
    }

    /// Enqueue every propagator (root propagation; naive backtrack).
    pub fn enqueue_all(&mut self) {
        let n = self.in_queue.len() as u32;
        for pid in 0..n {
            if pid == self.obj_pid && !self.has_obj {
                continue;
            }
            self.enqueue(pid);
        }
    }

    fn clear_on_conflict(&mut self) {
        self.queue_fast.clear();
        self.queue_slow.clear();
        self.ng.clear_queue();
        self.in_queue.iter_mut().for_each(|b| *b = false);
        // pending events of the failing pass are dropped; their trail
        // entries are undone before the next propagation, and the undo
        // path re-synchronises cumulative state from the restored
        // domains, so the diff-map invariant is preserved
        self.events.clear();
    }

    /// Re-synchronise the cumulative states of every item involving
    /// `vi` with the current domains (forward events and undo share
    /// this path — both just recompute the compulsory part).
    fn resync_var(&mut self, vi: usize) {
        crate::fail_point!("engine.resync");
        for k in self.cum_index.span(vi) {
            let (ci, ii) = *self.cum_index.at(k);
            let (ci, ii) = (ci as usize, ii as usize);
            let part = compulsory_part(&self.doms, &self.cum_states[ci].items[ii]);
            let cs = &mut self.cum_states[ci];
            let d = cs.items[ii].demand;
            debug_assert!(d != 0, "zero-demand items are never indexed for resync");
            if cs.reg[ii] != part {
                if let Some((a, b)) = cs.reg[ii] {
                    cs.data.apply(a, b, -d);
                    cs.nparts -= 1;
                }
                if let Some((a, b)) = part {
                    cs.data.apply(a, b, d);
                    cs.nparts += 1;
                }
                cs.reg[ii] = part;
                cs.version += 1;
                self.stats.cum_resyncs += 1;
            }
            if !cs.dirty_flag[ii] {
                cs.dirty_flag[ii] = true;
                cs.dirty.push(ii as u32);
            }
        }
    }

    /// Drain the typed-event buffer: wake matching watchers (all
    /// watchers in naive mode), wake the objective when its slack can
    /// tighten, and resync incremental cumulative state.
    fn drain_events(&mut self) {
        if self.events.is_empty() {
            return;
        }
        let mut events = std::mem::take(&mut self.events);
        for ev in events.drain(..) {
            let vi = ev.var.0 as usize;
            self.stats.events_posted += 1;
            for k in self.watch.span(vi) {
                let (w, wm) = *self.watch.at(k);
                if self.naive || (wm & ev.mask) != 0 {
                    self.enqueue(w);
                } else {
                    self.stats.wakeups_skipped += 1;
                }
            }
            // learned no-goods: wake the ones watching a literal this
            // event may have made true
            self.ng.on_event(vi as u32, ev.mask);
            if self.has_obj && (self.naive || (self.obj_mask[vi] & ev.mask) != 0) {
                self.enqueue(self.obj_pid);
            }
            if !self.naive && !self.cum_index.row_is_empty(vi) {
                self.resync_var(vi);
            }
        }
        // hand the (drained) buffer back to reuse its allocation
        self.events = events;
    }

    /// Run one propagator.
    fn run_prop(&mut self, model: &Model, pid: u32) -> Result<(), Conflict> {
        self.expl.reason = REASON_PROP;
        if pid == self.obj_pid {
            let mut ctx = Ctx {
                doms: &mut self.doms,
                trail: &mut self.trail,
                changed: &mut self.events,
                expl: &mut self.expl,
            };
            return prop_linear_le(&self.obj_terms, self.obj_rhs, &mut ctx);
        }
        // Disjunctive runs identically in naive and engine mode (the
        // intercept sits before the naive check), so the A/B knob never
        // perturbs naive-vs-engine tree equality.
        if let Propagator::Disjunctive { items } = &model.props[pid as usize] {
            if !self.disjunctive {
                return Ok(());
            }
            let mut ctx = Ctx {
                doms: &mut self.doms,
                trail: &mut self.trail,
                changed: &mut self.events,
                expl: &mut self.expl,
            };
            return prop_disjunctive(items, &mut ctx, &mut self.stats.disj_prunes);
        }
        if !self.naive {
            if let Some(ci) = self.cum_of_prop[pid as usize] {
                let cs = &mut self.cum_states[ci as usize];
                let mut ctx = Ctx {
                    doms: &mut self.doms,
                    trail: &mut self.trail,
                    changed: &mut self.events,
                    expl: &mut self.expl,
                };
                return cumulative_filter(cs, self.filtering, &mut ctx, &mut self.stats);
            }
        }
        let mut ctx = Ctx {
            doms: &mut self.doms,
            trail: &mut self.trail,
            changed: &mut self.events,
            expl: &mut self.expl,
        };
        model.props[pid as usize].propagate(&mut ctx)
    }

    /// Run one learned no-good (watched-literal propagation).
    fn run_nogood(&mut self, gid: u32) -> Result<(), Conflict> {
        let mut ctx = Ctx {
            doms: &mut self.doms,
            trail: &mut self.trail,
            changed: &mut self.events,
            expl: &mut self.expl,
        };
        self.ng.propagate(gid, &mut ctx, &mut self.stats)
    }

    /// Propagate to fixpoint: drain the cheap tier (model propagators
    /// and learned no-goods), then run one expensive propagator,
    /// repeat. `Err` leaves cleared queues (the caller backtracks).
    ///
    /// Aborts early — returning `Ok(())` with [`Self::aborted`] set —
    /// when the attached watchdog channel reports cancellation or the
    /// hard wall-clock stop has passed. An aborted pass leaves the
    /// domains mid-propagation (only ever narrowed, so still sound);
    /// the search loop checks the flag right after every fixpoint call
    /// and winds down as on a timeout instead of branching further.
    pub fn fixpoint(&mut self, model: &Model) -> Result<(), Conflict> {
        // both a spurious timeout and an error-return are modelled as
        // an abort: fixpoint has no error path that is sound to fake (a
        // fabricated Conflict would feed conflict analysis an
        // unexplainable clause)
        #[cfg(any(test, feature = "failpoints"))]
        if crate::util::failpoint::hit("engine.propagate").is_some() {
            self.aborted = true;
            return Ok(());
        }
        loop {
            if self.watchdog_tick() {
                self.aborted = true;
                return Ok(());
            }
            if let Some(gid) = self.ng.pop_queue() {
                self.stats.propagations += 1;
                if self.run_nogood(gid).is_err() {
                    self.clear_on_conflict();
                    return Err(Conflict);
                }
                self.drain_events();
                continue;
            }
            let pid = if let Some(p) = self.queue_fast.pop() {
                p
            } else if let Some(p) = self.queue_slow.pop() {
                p
            } else {
                return Ok(());
            };
            self.in_queue[pid as usize] = false;
            self.stats.propagations += 1;
            #[cfg(any(test, feature = "prop-audit"))]
            let audit_mark = self.trail.len();
            if self.run_prop(model, pid).is_err() {
                debug_conflict(model, pid, self.obj_pid);
                #[cfg(any(test, feature = "prop-audit"))]
                self.audit_conflict(model);
                self.clear_on_conflict();
                return Err(Conflict);
            }
            #[cfg(any(test, feature = "prop-audit"))]
            self.audit_entries(model, audit_mark);
            self.drain_events();
        }
    }

    /// Apply the left branch `x = v` and propagate to fixpoint.
    pub fn decide_eq(&mut self, model: &Model, x: VarId, v: i64) -> Result<(), Conflict> {
        let r = {
            self.expl.reason = REASON_DECISION;
            self.expl.scratch.clear();
            let mut ctx = Ctx {
                doms: &mut self.doms,
                trail: &mut self.trail,
                changed: &mut self.events,
                expl: &mut self.expl,
            };
            ctx.fix_var(x, v)
        };
        if r.is_err() {
            self.clear_on_conflict();
            return Err(Conflict);
        }
        self.drain_events();
        self.fixpoint(model)
    }

    /// Apply the right branch `x ≥ v` and propagate to fixpoint.
    pub fn decide_ge(&mut self, model: &Model, x: VarId, v: i64) -> Result<(), Conflict> {
        let r = {
            self.expl.reason = REASON_DECISION;
            self.expl.scratch.clear();
            let mut ctx = Ctx {
                doms: &mut self.doms,
                trail: &mut self.trail,
                changed: &mut self.events,
                expl: &mut self.expl,
            };
            ctx.set_min(x, v)
        };
        if r.is_err() {
            self.clear_on_conflict();
            return Err(Conflict);
        }
        self.drain_events();
        self.fixpoint(model)
    }

    /// Current decision level (number of open decisions; learned search).
    pub fn current_level(&self) -> usize {
        self.level_marks.len()
    }

    /// The decision level that established trail entry `idx`.
    pub fn level_of(&self, idx: u32) -> usize {
        self.level_marks.partition_point(|&m| m <= idx)
    }

    /// Open a new decision level, apply the decision literal `l`, and
    /// propagate to fixpoint (learned search's branching step — every
    /// decision is a single bound literal, so its negation is one too).
    pub fn decide_lit(&mut self, model: &Model, l: Lit) -> Result<(), Conflict> {
        self.level_marks.push(self.trail.len() as u32);
        let r = {
            self.expl.reason = REASON_DECISION;
            self.expl.scratch.clear();
            let mut ctx = Ctx {
                doms: &mut self.doms,
                trail: &mut self.trail,
                changed: &mut self.events,
                expl: &mut self.expl,
            };
            if l.is_lb {
                ctx.set_min(l.var, l.val)
            } else {
                ctx.set_max(l.var, l.val)
            }
        };
        if r.is_err() {
            self.clear_on_conflict();
            return Err(Conflict);
        }
        self.drain_events();
        self.fixpoint(model)
    }

    /// Undo down to decision level `level` (learned search's backjump),
    /// keeping learned no-goods and activities.
    pub fn backjump_to(&mut self, level: usize) {
        debug_assert!(level <= self.level_marks.len());
        if level >= self.level_marks.len() {
            return;
        }
        let mark = self.level_marks[level] as usize;
        self.undo_to(mark);
        self.level_marks.truncate(level);
    }

    /// Apply `l` as a root-level fact (the assertion of a size-1
    /// learned no-good) and propagate. `Err` means the root is
    /// infeasible under the current objective bound — the search space
    /// is exhausted.
    pub fn assert_root(&mut self, model: &Model, l: Lit) -> Result<(), Conflict> {
        debug_assert!(self.level_marks.is_empty());
        let r = {
            self.expl.reason = REASON_PROP;
            self.expl.scratch.clear();
            let mut ctx = Ctx {
                doms: &mut self.doms,
                trail: &mut self.trail,
                changed: &mut self.events,
                expl: &mut self.expl,
            };
            if l.is_lb {
                ctx.set_min(l.var, l.val)
            } else {
                ctx.set_max(l.var, l.val)
            }
        };
        if r.is_err() {
            self.clear_on_conflict();
            return Err(Conflict);
        }
        self.drain_events();
        self.fixpoint(model)
    }

    /// Undo the trail down to `mark`: restore domains, re-synchronise
    /// cumulative state, and re-enqueue only the propagators watching
    /// undone variables plus the objective — instead of the whole
    /// propagator set. The restored state was itself a propagation
    /// fixpoint, so for idempotent propagators even the undone-var
    /// watchers would be redundant; they are re-enqueued anyway as
    /// cheap insurance for bounded-effort passes (`Cumulative` caps its
    /// per-invocation shaving), while the objective genuinely needs the
    /// wake because its rhs may have tightened since the subtree was
    /// entered. In naive mode every propagator is re-enqueued instead.
    pub fn undo_to(&mut self, mark: usize) {
        while self.trail.len() > mark {
            let Some(e) = self.trail.pop() else { break };
            self.doms.restore(VarId(e.var), (e.old_lo, e.old_hi));
            if self.expl.enabled {
                // keep the provenance columns, per-var entry chain and
                // the explanation arena in lock-step with the trail
                // (learned no-good watches need no update: undoing only
                // makes watched literals less true, which preserves the
                // invariant)
                let prev = self.expl.pop_meta();
                self.expl.last_entry[e.var as usize] = prev;
            }
            if self.naive {
                continue;
            }
            let vi = e.var as usize;
            for k in self.watch.span(vi) {
                let (w, _) = *self.watch.at(k);
                self.enqueue(w);
            }
            if !self.cum_index.row_is_empty(vi) {
                self.resync_var(vi);
            }
        }
        if self.naive {
            self.enqueue_all();
        } else if self.has_obj {
            self.enqueue(self.obj_pid);
        }
    }
}

/// `MOCCASIN_DEBUG_PROP` conflict reporting; the env lookup happens
/// once per process (cached in a `OnceLock`), not on every conflict.
fn debug_conflict(model: &Model, pid: u32, obj_pid: u32) {
    static DEBUG: OnceLock<bool> = OnceLock::new();
    let on = *DEBUG.get_or_init(|| std::env::var("MOCCASIN_DEBUG_PROP").is_ok());
    if !on {
        return;
    }
    let kind = if pid == obj_pid {
        "objective".to_string()
    } else {
        match &model.props[pid as usize] {
            Propagator::LinearLe { rhs, terms } => {
                format!("LinearLe(rhs={rhs},terms={})", terms.len())
            }
            Propagator::LeOffset { .. } => "LeOffset".into(),
            Propagator::Cumulative { .. } => "Cumulative".into(),
            Propagator::Cover { targets, candidates } => {
                format!("Cover({} targets, {} candidates)", targets.len(), candidates.len())
            }
            Propagator::AllDifferent { .. } => "AllDifferent".into(),
            Propagator::Disjunctive { items } => {
                format!("Disjunctive({} items)", items.len())
            }
        }
    };
    eprintln!("conflict in {kind}");
}

/// Per-engine budget of explanation-soundness audits: enough to cover
/// every pruning of the small models unit tests solve, while bounding
/// the overhead on the larger property-test instances.
#[cfg(any(test, feature = "prop-audit"))]
const AUDIT_CAP: u64 = 20_000;

/// Explanation-soundness audit (test / `prop-audit` builds): every
/// explanation a propagator records — the premise of a pruning or a
/// conflict — is replayed against a fresh propagation from the *root*
/// domains, and the claimed consequence must be re-derived. An unsound
/// conjunction (one that does not imply what it explains) would
/// otherwise surface only as a wrong learned no-good, far from the
/// propagator that emitted it; the audit panics at the source instead.
///
/// Only entries created inside `run_prop` are audited: decisions and
/// root assertions carry no explanation, and no-good propagations
/// (`run_nogood`) derive from learned clauses that are not re-derivable
/// from the model's propagators alone.
#[cfg(any(test, feature = "prop-audit"))]
impl PropagationEngine {
    /// Root-state copy of the domains: the current domains with every
    /// trail entry at or above the first decision undone. Holes carved
    /// at root (including `assert_root` facts and the root fixpoint)
    /// are kept — recorded literals are post-snap values over the same
    /// root holes, so the replay must share them.
    fn audit_root_domains(&self) -> DomStore {
        let mut doms = self.doms.clone();
        let root = self.level_marks.first().map_or(self.trail.len(), |&m| m as usize);
        for e in self.trail[root..].iter().rev() {
            doms.restore(VarId(e.var), (e.old_lo, e.old_hi));
        }
        doms
    }

    /// Audit every trail entry recorded by the `run_prop` call that just
    /// returned `Ok` (`mark` = trail length before the call).
    fn audit_entries(&mut self, model: &Model, mark: usize) {
        if !self.expl.enabled || self.audits_done >= AUDIT_CAP || self.trail.len() == mark {
            return;
        }
        let root = self.audit_root_domains();
        for idx in mark..self.trail.len() {
            if self.audits_done >= AUDIT_CAP {
                return;
            }
            self.audits_done += 1;
            debug_assert_eq!(
                self.expl.reason_of[idx],
                REASON_PROP,
                "audit outside a propagator pass"
            );
            let lit = self.expl.lit[idx];
            let premise: Vec<Lit> = self.expl.expl_window(idx as u32).to_vec();
            audit_replay(
                model,
                &self.obj_terms,
                self.obj_rhs,
                self.has_obj,
                self.filtering,
                self.disjunctive,
                root.clone(),
                &premise,
                Some(lit),
            );
        }
    }

    /// Audit the conflict explanation the failing `run_prop` call left
    /// in `expl.conflict`: replayed from root, the conjunction must be
    /// refutable by propagation.
    fn audit_conflict(&mut self, model: &Model) {
        if !self.expl.enabled || self.audits_done >= AUDIT_CAP || self.expl.conflict.is_empty()
        {
            return;
        }
        self.audits_done += 1;
        let premise = self.expl.conflict.clone();
        audit_replay(
            model,
            &self.obj_terms,
            self.obj_rhs,
            self.has_obj,
            self.filtering,
            self.disjunctive,
            self.audit_root_domains(),
            &premise,
            None,
        );
    }
}

/// Replay one recorded explanation: apply `premise` to the root
/// `domains`, propagate every model propagator (plus the objective
/// bound) to fixpoint, and check the consequence — `target` literal
/// entailed (`Some`), or the premise refuted (`None`). A conflict
/// during replay always passes: for conflict audits it is the expected
/// refutation, for pruning audits it entails everything vacuously.
#[cfg(any(test, feature = "prop-audit"))]
#[allow(clippy::too_many_arguments)]
fn audit_replay(
    model: &Model,
    obj_terms: &[(i64, VarId)],
    obj_rhs: i64,
    has_obj: bool,
    filtering: FilteringMode,
    disjunctive: bool,
    mut doms: DomStore,
    premise: &[Lit],
    target: Option<Lit>,
) {
    let mut trail: Vec<TrailEntry> = Vec::new();
    let mut changed: Vec<DomainEvent> = Vec::new();
    let mut expl = ExplState::new(doms.len(), false);
    {
        let mut ctx = Ctx {
            doms: &mut doms,
            trail: &mut trail,
            changed: &mut changed,
            expl: &mut expl,
        };
        for &l in premise {
            let r = if l.is_lb { ctx.set_min(l.var, l.val) } else { ctx.set_max(l.var, l.val) };
            if r.is_err() {
                return; // premise self-contradictory at root: vacuous
            }
        }
    }
    loop {
        let before = trail.len();
        let mut failed = false;
        {
            let mut ctx = Ctx {
                doms: &mut doms,
                trail: &mut trail,
                changed: &mut changed,
                expl: &mut expl,
            };
            for p in model.props.iter() {
                let r = match p {
                    Propagator::Cumulative { items, cap } => {
                        replay_cumulative(items, *cap, filtering, &mut ctx)
                    }
                    Propagator::Disjunctive { .. } if !disjunctive => Ok(()),
                    _ => p.propagate(&mut ctx),
                };
                if r.is_err() {
                    failed = true;
                    break;
                }
            }
            if !failed && has_obj && prop_linear_le(obj_terms, obj_rhs, &mut ctx).is_err() {
                failed = true;
            }
        }
        if failed {
            return; // refuted: the audited consequence holds vacuously
        }
        if trail.len() == before {
            break; // fixpoint
        }
        changed.clear();
    }
    match target {
        Some(l) => assert!(
            l.is_true_in(&doms),
            "unsound explanation: {premise:?} does not entail {l:?} \
             (replay reached min={} max={})",
            doms.min(l.var),
            doms.max(l.var),
        ),
        None => panic!("unsound conflict explanation: {premise:?} is consistent under replay"),
    }
}

/// The audit replay's `Cumulative` pass: a from-scratch compulsory-part
/// profile with overload check, timetable filtering, and — when the
/// audited engine ran edge-finding — the same edge-finding pass, so EF
/// prunings are re-derivable. Interval validity (`active → start ≤ end`,
/// the model's constraint-(2) pairing the timetable coupling assumes)
/// is applied explicitly first, making the coupling's prunings
/// re-derivable on any model, paired or not.
#[cfg(any(test, feature = "prop-audit"))]
fn replay_cumulative(
    items: &[CumItem],
    cap: i64,
    filtering: FilteringMode,
    ctx: &mut Ctx,
) -> Result<(), Conflict> {
    for it in items {
        if ctx.min(it.active) == 1 {
            let s = ctx.min(it.start);
            if ctx.min(it.end) < s {
                ctx.set_min(it.end, s)?;
            }
            let e = ctx.max(it.end);
            if ctx.max(it.start) > e {
                ctx.set_max(it.start, e)?;
            }
        }
    }
    let mut diff: BTreeMap<i64, i64> = BTreeMap::new();
    let mut nparts = 0u32;
    for it in items {
        if it.demand == 0 {
            continue;
        }
        if let Some((a, b)) = compulsory_part(ctx.doms, it) {
            add_diff(&mut diff, a, it.demand);
            add_diff(&mut diff, b + 1, -it.demand);
            nparts += 1;
        }
    }
    if nparts == 0 {
        return Ok(());
    }
    let mut profile: Vec<(i64, i64)> = Vec::with_capacity(diff.len());
    let mut load = 0i64;
    let mut max_load = 0i64;
    for (&t, &d) in diff.iter() {
        load += d;
        profile.push((t, load));
        max_load = max_load.max(load);
    }
    if max_load > cap {
        return ctx.fail();
    }
    let view = ProfileView::Steps(&profile);
    let mut ef_prunes = 0u64;
    for ii in 0..items.len() {
        timetable_filter_item(items, ii, cap, &view, ctx)?;
        if filtering == FilteringMode::EdgeFinding {
            edge_finding_filter_item(items, ii, cap, &view, ctx, &mut ef_prunes)?;
        }
    }
    Ok(())
}
