//! Constraint propagators (bounds-consistency filtering).
//!
//! Each propagator implements three things: the variables it watches,
//! a `propagate` pass that tightens bounds / detects conflict, and a
//! full-assignment `is_satisfied` check used to verify every emitted
//! solution. Filtering strength is deliberately "timetable-grade" — the
//! exactness of the solver comes from search; the final check makes
//! soundness unconditional.

use super::disjunctive::{disj_satisfied, prop_disjunctive, DisjItem};
use super::domain::{event, DomainEvent, DomStore, Lit, VarId};
use super::segtree::SegTreeProfile;
use std::sync::Arc;

/// One trailed bound change: exactly the restore data the undo path
/// reads. Provenance for conflict analysis lives in *parallel*
/// structure-of-arrays columns inside [`ExplState`], filled only when
/// explanations are enabled — the chronological / naive hot path keeps
/// the lean 12-byte entry and pays nothing for the learned machinery.
#[derive(Debug, Clone, Copy)]
pub(crate) struct TrailEntry {
    /// The variable whose bounds changed.
    pub var: u32,
    /// Trailed low index bound to restore on undo.
    pub old_lo: u32,
    /// Trailed high index bound to restore on undo.
    pub old_hi: u32,
}

/// `ExplState::prev` sentinel: no earlier entry writes this variable.
pub(crate) const NO_ENTRY: u32 = u32::MAX;
/// `ExplState::reason_of` tag: the entry is a search decision
/// (unexplainable; conflict analysis keeps its literal in the no-good).
pub(crate) const REASON_DECISION: u32 = u32::MAX;
/// `ExplState::reason_of` tag: the entry was set by a model propagator
/// (its explanation, if any, lives in the arena window).
pub(crate) const REASON_PROP: u32 = u32::MAX - 1;

/// Explanation state shared by the engine and every propagation pass:
/// per-trail-entry provenance columns, the flat literal arena holding
/// every entry's explanation window, the scratch buffer propagators
/// fill before each tightening, the conflict explanation of the latest
/// failure, and the per-variable latest trail entry index. All dormant
/// when `enabled` is false (chronological / naive search skips every
/// explanation cost).
///
/// Provenance is stored structure-of-arrays: 1UIP analysis walks
/// `reason_of` / `prev` / `old_val` in tight loops over many entries,
/// and the columns it touches stay packed instead of striding over a
/// 40-byte per-entry struct. Explanation windows are *offsets*: entry
/// `i` explains itself with `arena[expl_off[i] .. expl_off[i+1]]`.
/// The windows tile the arena exactly — `push_meta` appends the
/// scratch explanation at `arena.len()` and undo truncates in
/// lock-step — so one `u32` offset column replaces the old per-entry
/// `(start, len)` pairs.
#[derive(Debug, Default)]
pub(crate) struct ExplState {
    /// Per-entry: the bound predicate the entry established (post-snap
    /// value). Parallel to the trail when `enabled`.
    pub lit: Vec<Lit>,
    /// Per-entry: value of the same bound *before* the change
    /// (previous min for an LB entry, previous max for a UB entry) —
    /// lets analysis detect root-entailed literals without replaying
    /// the trail.
    pub old_val: Vec<i64>,
    /// Per-entry: previous trail index writing the same variable
    /// ([`NO_ENTRY`] = none).
    pub prev: Vec<u32>,
    /// Per-entry: [`REASON_DECISION`], [`REASON_PROP`], or the id of
    /// the learned no-good whose propagation set this bound (for
    /// activity bumping).
    pub reason_of: Vec<u32>,
    /// Explanation-window offsets into `arena`: entry `i`'s window is
    /// `[expl_off[i], expl_off[i+1])`. Length = entries + 1; the
    /// trailing element always equals `arena.len()`.
    pub expl_off: Vec<u32>,
    /// Flat arena of explanation literals; truncated in lock-step with
    /// the trail.
    pub arena: Vec<Lit>,
    /// Scratch explanation for the *next* tightening; copied into the
    /// arena by `Ctx::set_min` / `Ctx::set_max` on success.
    pub scratch: Vec<Lit>,
    /// Explanation of the most recent conflict (filled on failure).
    pub conflict: Vec<Lit>,
    /// var → latest trail entry index writing it ([`NO_ENTRY`] = none).
    pub last_entry: Vec<u32>,
    /// Reason tag stamped on entries pushed by the current pass.
    pub reason: u32,
    /// Whether explanations are recorded at all.
    pub enabled: bool,
    /// Scratch index buffer reused by `Cover` passes (the
    /// possible-candidate list) — one buffer per engine instead of one
    /// heap allocation per propagation. Lives here because `ExplState`
    /// is the per-pass state already threaded into every `Ctx`.
    pub cover_scratch: Vec<u32>,
}

impl ExplState {
    /// Fresh state for `nvars` variables; `enabled` selects whether any
    /// explanation work happens.
    pub fn new(nvars: usize, enabled: bool) -> Self {
        let mut s = ExplState::default();
        s.reset(nvars, enabled);
        s
    }

    /// Re-initialize for a new solve over `nvars` variables, keeping
    /// every buffer's capacity (the solve-context reuse path).
    pub fn reset(&mut self, nvars: usize, enabled: bool) {
        self.lit.clear();
        self.old_val.clear();
        self.prev.clear();
        self.reason_of.clear();
        self.expl_off.clear();
        self.expl_off.push(0);
        self.arena.clear();
        self.scratch.clear();
        self.conflict.clear();
        self.last_entry.clear();
        if enabled {
            self.last_entry.resize(nvars, NO_ENTRY);
        }
        self.reason = REASON_PROP;
        self.enabled = enabled;
        self.cover_scratch.clear();
    }

    /// Number of provenance entries recorded (equals the trail length
    /// when `enabled`).
    #[inline]
    pub fn len(&self) -> usize {
        self.lit.len()
    }

    /// Entry `entry`'s explanation window in the arena.
    #[inline]
    pub fn expl_window(&self, entry: u32) -> &[Lit] {
        let e = entry as usize;
        &self.arena[self.expl_off[e] as usize..self.expl_off[e + 1] as usize]
    }

    /// Record provenance for the entry just pushed on the trail. The
    /// caller has already appended the scratch explanation to `arena`;
    /// this closes the window by pushing the new arena length.
    #[inline]
    pub fn push_meta(&mut self, lit: Lit, old_val: i64, prev: u32) {
        self.lit.push(lit);
        self.old_val.push(old_val);
        self.prev.push(prev);
        self.reason_of.push(self.reason);
        self.expl_off.push(self.arena.len() as u32);
    }

    /// Undo the most recent provenance entry, truncating its arena
    /// window; returns its `prev` link (for `last_entry` restoration).
    #[inline]
    pub fn pop_meta(&mut self) -> u32 {
        self.lit.pop();
        self.old_val.pop();
        self.reason_of.pop();
        self.expl_off.pop();
        // `expl_off` carries a base entry, so `last` only misses if the
        // columns were popped past empty — degrade to a full arena clear
        // and a NO_ENTRY link rather than panicking mid-backtrack.
        let base = self.expl_off.last().copied().unwrap_or(0);
        self.arena.truncate(base as usize);
        self.prev.pop().unwrap_or(NO_ENTRY)
    }
}

/// One optional interval contributing `demand` to a cumulative resource
/// while active over `[start, end]` (inclusive, as in the paper: the
/// memory block lives from the compute event through the last retention
/// event).
#[derive(Debug, Clone)]
pub struct CumItem {
    /// Boolean: the interval exists.
    pub active: VarId,
    /// First event covered by the interval.
    pub start: VarId,
    /// Last event covered by the interval (inclusive).
    pub end: VarId,
    /// Resource units consumed while active.
    pub demand: i64,
}

/// A constraint: watched variables + a bounds-filtering pass + a
/// full-assignment satisfaction check (static dispatch via this enum).
#[derive(Debug, Clone)]
pub enum Propagator {
    /// Σ cᵢ·xᵢ ≤ rhs.
    LinearLe { terms: Vec<(i64, VarId)>, rhs: i64 },
    /// (b = 1 →) x + c ≤ y.
    LeOffset { b: Option<VarId>, x: VarId, c: i64, y: VarId },
    /// Renewable resource: Σ_{i active, start_i ≤ t ≤ end_i} demand_i ≤ cap ∀t.
    Cumulative { items: Vec<CumItem>, cap: i64 },
    /// Per target `(active, start)`:
    /// active = 1 → ∃ (a, s, e) ∈ candidates: a = 1 ∧ s + 1 ≤ start ≤ e.
    ///
    /// Targets and candidates are shared slices (`Arc`): the model
    /// builder emits one `Cover` per precedence edge covering *all*
    /// consumer copies, and every cover of the same producer shares one
    /// candidate array instead of cloning a `Vec` per copy.
    Cover { targets: Arc<[(VarId, VarId)]>, candidates: Arc<[(VarId, VarId, VarId)]> },
    /// Pairwise distinct values.
    AllDifferent { vars: Vec<VarId> },
    /// Unary resource over a presolve-detected heavy clique: active
    /// intervals are pairwise disjoint (redundant with `Cumulative` —
    /// any two members' demands exceed its capacity — but propagates
    /// order information the timetable cannot see; see
    /// `cp::disjunctive`). Gated at propagation time by
    /// `SearchStrategy::disjunctive`.
    Disjunctive { items: Vec<DisjItem> },
}

/// Conflict marker.
pub struct Conflict;

/// Mutable propagation context: domains + trail + typed event log +
/// explanation state.
pub struct Ctx<'a> {
    /// All variable bounds, in the engine's SoA store.
    pub doms: &'a mut DomStore,
    /// Trailed bound changes — undone in reverse order on backtrack.
    pub(crate) trail: &'a mut Vec<TrailEntry>,
    /// Typed domain events posted during the current pass (drained by
    /// the propagation engine after the propagator returns).
    pub changed: &'a mut Vec<DomainEvent>,
    /// Explanation state (arena/scratch/conflict buffers); dormant when
    /// explanations are disabled.
    pub(crate) expl: &'a mut ExplState,
}

impl<'a> Ctx<'a> {
    /// Whether explanations are being recorded — propagators gate every
    /// explanation-literal computation on this so the chronological /
    /// naive paths pay nothing.
    #[inline]
    pub fn explaining(&self) -> bool {
        self.expl.enabled
    }

    /// Start a fresh scratch explanation for the next tightening(s).
    #[inline]
    pub fn begin_expl(&mut self) {
        self.expl.scratch.clear();
    }

    /// Append one literal to the scratch explanation.
    #[inline]
    pub fn expl_push(&mut self, l: Lit) {
        self.expl.scratch.push(l);
    }

    /// Fail the current pass with the scratch buffer as the conflict
    /// explanation (for failures detected without a bound wipe-out,
    /// e.g. a negative slack or an uncoverable active target).
    pub fn fail(&mut self) -> Result<(), Conflict> {
        if self.expl.enabled {
            std::mem::swap(&mut self.expl.conflict, &mut self.expl.scratch);
        }
        Err(Conflict)
    }

    /// Push the trail entry for a successful tightening of `x`; when
    /// explaining, also copy the scratch explanation into the arena and
    /// record the provenance columns.
    fn push_entry(&mut self, x: VarId, old: (u32, u32), lit: Lit, old_val: i64) {
        if self.expl.enabled {
            // the scratch window lands at arena.len(), tiling the
            // arena exactly; push_meta closes it with the new length
            self.expl.arena.extend_from_slice(&self.expl.scratch);
            let idx = self.trail.len() as u32;
            let prev = std::mem::replace(&mut self.expl.last_entry[x.0 as usize], idx);
            self.expl.push_meta(lit, old_val, prev);
        }
        self.trail.push(TrailEntry { var: x.0, old_lo: old.0, old_hi: old.1 });
    }

    /// Lower bound of `x`.
    #[inline]
    pub fn min(&self, x: VarId) -> i64 {
        self.doms.min(x)
    }

    /// Upper bound of `x`.
    #[inline]
    pub fn max(&self, x: VarId) -> i64 {
        self.doms.max(x)
    }

    /// Whether `x` is fixed.
    #[inline]
    pub fn is_fixed(&self, x: VarId) -> bool {
        self.doms.is_fixed(x)
    }

    /// x ≥ v.
    pub fn set_min(&mut self, x: VarId, v: i64) -> Result<(), Conflict> {
        let old_min = self.doms.min(x);
        let old = self.doms.bounds(x);
        match self.doms.remove_below(x, v) {
            Ok(true) => {
                let fixed = self.doms.is_fixed(x);
                let mask = event::LB | if fixed { event::FIX } else { 0 };
                // post-snap value: explicit domains may skip holes; the
                // extra strength over `v` is a root-domain fact, so the
                // scratch explanation still covers the recorded literal
                let lit = Lit::geq(x, self.doms.min(x));
                self.push_entry(x, old, lit, old_min);
                self.changed.push(DomainEvent { var: x, mask });
                Ok(())
            }
            Ok(false) => Ok(()),
            Err(()) => {
                // wipe-out is detected before any bound write, so
                // there is nothing to restore
                if self.expl.enabled {
                    // scratch ⟹ x ≥ v, which contradicts x ≤ max(x)
                    let ub = Lit::leq(x, self.doms.max(x));
                    std::mem::swap(&mut self.expl.conflict, &mut self.expl.scratch);
                    self.expl.conflict.push(ub);
                }
                Err(Conflict)
            }
        }
    }

    /// x ≤ v.
    pub fn set_max(&mut self, x: VarId, v: i64) -> Result<(), Conflict> {
        let old_max = self.doms.max(x);
        let old = self.doms.bounds(x);
        match self.doms.remove_above(x, v) {
            Ok(true) => {
                let fixed = self.doms.is_fixed(x);
                let mask = event::UB | if fixed { event::FIX } else { 0 };
                let lit = Lit::leq(x, self.doms.max(x));
                self.push_entry(x, old, lit, old_max);
                self.changed.push(DomainEvent { var: x, mask });
                Ok(())
            }
            Ok(false) => Ok(()),
            Err(()) => {
                if self.expl.enabled {
                    let lb = Lit::geq(x, self.doms.min(x));
                    std::mem::swap(&mut self.expl.conflict, &mut self.expl.scratch);
                    self.expl.conflict.push(lb);
                }
                Err(Conflict)
            }
        }
    }

    /// x = v.
    pub fn fix_var(&mut self, x: VarId, v: i64) -> Result<(), Conflict> {
        self.set_min(x, v)?;
        self.set_max(x, v)
    }
}

impl Propagator {
    /// Watched variables with the event mask (see [`event`]) that can
    /// enable new filtering for this propagator. The propagation engine
    /// wakes the propagator only on matching events; non-matching
    /// changes are counted as skipped wakeups.
    ///
    /// Masks mirror exactly what `propagate` *reads*:
    /// * `LinearLe` reads `min` of positive-coefficient terms and `max`
    ///   of negative ones (the slack computation) — `LB` / `UB`.
    /// * `LeOffset` reads `min(x)`, `max(y)` and (when guarded)
    ///   `min(b)` — the guard becoming false makes it vacuous, which
    ///   never enables filtering.
    /// * `Cumulative` reads both bounds of every interval variable.
    /// * `Cover` reads both bounds of the covered start, `min(active)`,
    ///   and per candidate `max(a)`, `min(s)`, `max(e)`.
    /// * `AllDifferent` reads everything.
    /// * `Disjunctive` reads `min(active)` (an activation can certify a
    ///   member; `max(active)` dropping to 0 only makes pairs vacuous),
    ///   `min(end)` and `max(start)` — the bounds that close an order.
    pub fn watch_masks(&self) -> Vec<(VarId, u8)> {
        match self {
            Propagator::LinearLe { terms, .. } => terms
                .iter()
                .filter(|&&(c, _)| c != 0)
                .map(|&(c, v)| (v, if c > 0 { event::LB } else { event::UB }))
                .collect(),
            Propagator::LeOffset { b, x, y, .. } => {
                let mut w = vec![(*x, event::LB), (*y, event::UB)];
                if let Some(b) = b {
                    w.push((*b, event::LB));
                }
                w
            }
            Propagator::Cumulative { items, .. } => items
                .iter()
                .flat_map(|i| {
                    [
                        (i.active, event::LB | event::UB),
                        (i.start, event::LB | event::UB),
                        (i.end, event::LB | event::UB),
                    ]
                })
                .collect(),
            Propagator::Cover { targets, candidates } => {
                let mut w = Vec::with_capacity(targets.len() * 2 + candidates.len() * 3);
                for &(active, start) in targets.iter() {
                    w.push((active, event::LB));
                    w.push((start, event::LB | event::UB));
                }
                for &(a, s, e) in candidates.iter() {
                    w.extend([(a, event::UB), (s, event::LB), (e, event::UB)]);
                }
                w
            }
            Propagator::AllDifferent { vars } => {
                vars.iter().map(|&v| (v, event::ANY)).collect()
            }
            Propagator::Disjunctive { items } => items
                .iter()
                .flat_map(|i| {
                    [(i.active, event::LB), (i.start, event::UB), (i.end, event::LB)]
                })
                .collect(),
        }
    }

    /// Bounds filtering.
    pub fn propagate(&self, ctx: &mut Ctx) -> Result<(), Conflict> {
        match self {
            Propagator::LinearLe { terms, rhs } => prop_linear_le(terms, *rhs, ctx),
            Propagator::LeOffset { b, x, c, y } => {
                if let Some(b) = b {
                    if ctx.max(*b) == 0 {
                        return Ok(()); // guard false: constraint vacuous
                    }
                    if ctx.min(*b) == 0 {
                        // guard undetermined: only check for entailment of
                        // infeasibility → b must be 0
                        if ctx.min(*x) + c > ctx.max(*y) {
                            if ctx.explaining() {
                                ctx.begin_expl();
                                let lx = Lit::geq(*x, ctx.min(*x));
                                let ly = Lit::leq(*y, ctx.max(*y));
                                ctx.expl_push(lx);
                                ctx.expl_push(ly);
                            }
                            return ctx.set_max(*b, 0);
                        }
                        return Ok(());
                    }
                }
                // enforce x + c <= y
                if ctx.explaining() {
                    ctx.begin_expl();
                    let lx = Lit::geq(*x, ctx.min(*x));
                    ctx.expl_push(lx);
                    if let Some(b) = b {
                        ctx.expl_push(Lit::geq(*b, 1));
                    }
                }
                ctx.set_min(*y, ctx.min(*x) + c)?;
                if ctx.explaining() {
                    ctx.begin_expl();
                    let ly = Lit::leq(*y, ctx.max(*y));
                    ctx.expl_push(ly);
                    if let Some(b) = b {
                        ctx.expl_push(Lit::geq(*b, 1));
                    }
                }
                ctx.set_max(*x, ctx.max(*y) - c)
            }
            Propagator::Cumulative { items, cap } => prop_cumulative(items, *cap, ctx),
            Propagator::Cover { targets, candidates } => {
                // reuse the engine's scratch buffer for the
                // possible-candidate list (taken, not borrowed, so the
                // pass can still mutate ctx; handed back on every exit)
                let mut possible = std::mem::take(&mut ctx.expl.cover_scratch);
                let mut r = Ok(());
                for &(active, start) in targets.iter() {
                    r = prop_cover(active, start, candidates, &mut possible, ctx);
                    if r.is_err() {
                        break;
                    }
                }
                possible.clear();
                ctx.expl.cover_scratch = possible;
                r
            }
            Propagator::AllDifferent { vars } => prop_all_different(vars, ctx),
            Propagator::Disjunctive { items } => {
                // direct calls (naive reference, audit replay, tests)
                // discard the prune count; the engine intercepts this
                // variant in `run_prop` to count into `SearchStats`
                let mut prunes = 0u64;
                prop_disjunctive(items, ctx, &mut prunes)
            }
        }
    }

    /// Full-assignment satisfaction check.
    pub fn is_satisfied(&self, a: &[i64]) -> bool {
        let val = |v: VarId| a[v.0 as usize];
        match self {
            Propagator::LinearLe { terms, rhs } => {
                terms.iter().map(|&(c, v)| c * val(v)).sum::<i64>() <= *rhs
            }
            Propagator::LeOffset { b, x, c, y } => {
                b.map(|b| val(b) == 0).unwrap_or(false) || val(*x) + c <= val(*y)
            }
            Propagator::Cumulative { items, cap } => {
                // load only changes at interval starts
                for probe in items.iter().filter(|i| val(i.active) == 1) {
                    let t = val(probe.start);
                    let load: i64 = items
                        .iter()
                        .filter(|j| val(j.active) == 1)
                        .filter(|j| val(j.start) <= t && t <= val(j.end))
                        .map(|j| j.demand)
                        .sum();
                    if load > *cap {
                        return false;
                    }
                }
                true
            }
            Propagator::Cover { targets, candidates } => {
                targets.iter().all(|&(active, start)| {
                    if val(active) == 0 {
                        return true;
                    }
                    let t = val(start);
                    candidates
                        .iter()
                        .any(|&(a_, s, e)| val(a_) == 1 && val(s) + 1 <= t && t <= val(e))
                })
            }
            Propagator::AllDifferent { vars } => {
                let mut vals: Vec<i64> = vars.iter().map(|&v| val(v)).collect();
                vals.sort_unstable();
                vals.windows(2).all(|w| w[0] != w[1])
            }
            Propagator::Disjunctive { items } => disj_satisfied(items, a),
        }
    }
}

/// Σ c·x ≤ rhs bounds filtering (`pub(crate)`: also backs the engine's
/// persistent objective-bound propagator, whose rhs tightens in place).
pub(crate) fn prop_linear_le(
    terms: &[(i64, VarId)],
    rhs: i64,
    ctx: &mut Ctx,
) -> Result<(), Conflict> {
    // Explanation of the slack computation: the bound each term
    // contributes through. `skip` omits the pruned variable itself —
    // `min(v) + ⌊slack/c⌋` equals the bound implied by the *other*
    // terms alone, so the pruned variable's own bound is not part of
    // the reason.
    fn explain_slack(terms: &[(i64, VarId)], skip: Option<VarId>, ctx: &mut Ctx) {
        ctx.begin_expl();
        for &(c, v) in terms {
            if Some(v) == skip {
                continue;
            }
            if c > 0 {
                let l = Lit::geq(v, ctx.min(v));
                ctx.expl_push(l);
            } else if c < 0 {
                let l = Lit::leq(v, ctx.max(v));
                ctx.expl_push(l);
            }
        }
    }
    // min possible sum
    let mut minsum: i64 = 0;
    for &(c, v) in terms {
        minsum += if c >= 0 { c * ctx.min(v) } else { c * ctx.max(v) };
    }
    let slack = rhs - minsum;
    if slack < 0 {
        if ctx.explaining() {
            explain_slack(terms, None, ctx);
        }
        return ctx.fail();
    }
    for &(c, v) in terms {
        if c > 0 {
            let room = slack / c;
            let ub = ctx.min(v) + room;
            if ub < ctx.max(v) {
                if ctx.explaining() {
                    explain_slack(terms, Some(v), ctx);
                }
                ctx.set_max(v, ub)?;
            }
        } else if c < 0 {
            let room = slack / (-c);
            let lb = ctx.max(v) - room;
            if lb > ctx.min(v) {
                if ctx.explaining() {
                    explain_slack(terms, Some(v), ctx);
                }
                ctx.set_min(v, lb)?;
            }
        }
    }
    Ok(())
}

/// Load of a compressed step profile `(time, load on [time, next))`
/// at time `t` (shared by the naive propagator and the engine's
/// incremental cache).
pub(crate) fn profile_load_at(profile: &[(i64, i64)], t: i64) -> i64 {
    match profile.binary_search_by(|&(pt, _)| pt.cmp(&t)) {
        Ok(k) => profile[k].1,
        Err(0) => 0,
        Err(k) => profile[k - 1].1,
    }
}

/// Read-only view over a compulsory-part profile — the one filtering
/// implementation ([`timetable_filter_item`]) runs against either
/// representation, so the linear and the segment-tree timetable can
/// never drift apart:
///
/// * [`ProfileView::Steps`] — the flattened `(time, load)` step vector
///   (the naive propagator's from-scratch profile and the engine's
///   `--profile linear` diff-map cache; retained as the fuzz oracle).
/// * [`ProfileView::Tree`] — the engine's sparse lazy segment tree
///   (`--profile segtree`, the default): O(log H) point loads and
///   first-overload queries instead of O(K) scans.
///
/// Both views answer every query with identical *values* (loads are
/// step functions over the same breakpoints), so filtering — and hence
/// the explored search tree — is representation-independent.
pub(crate) enum ProfileView<'a> {
    /// Flattened step profile, breakpoints ascending.
    Steps(&'a [(i64, i64)]),
    /// Sparse lazy range-add / max segment tree.
    Tree(&'a SegTreeProfile),
}

impl ProfileView<'_> {
    /// Load at time `t`.
    #[inline]
    pub fn load_at(&self, t: i64) -> i64 {
        match self {
            ProfileView::Steps(p) => profile_load_at(p, t),
            ProfileView::Tree(t_) => t_.load_at(t),
        }
    }

    /// Earliest `t ∈ {lo} ∪ [lo, hi]` with `load(t) > cap`, if any.
    /// The point `lo` is probed even when `lo > hi` — a degenerate
    /// window can reach the fixed-placement check transiently (before
    /// the interval-validity pair prunes it), and the historical linear
    /// scan probed `load(s)` unconditionally; the tree arm mirrors that
    /// exactly so both views stay witness-identical. Within a proper
    /// window the step scan and the tree descent return the *same*
    /// time: the load only changes at part boundaries, and both report
    /// the leftmost point of the first region exceeding `cap`.
    pub fn first_over(&self, lo: i64, hi: i64, cap: i64) -> Option<i64> {
        match self {
            ProfileView::Steps(p) => {
                if profile_load_at(p, lo) > cap {
                    return Some(lo);
                }
                for &(t, l) in p.iter() {
                    if t > hi {
                        break;
                    }
                    if t >= lo && l > cap {
                        return Some(t);
                    }
                }
                None
            }
            ProfileView::Tree(t_) => {
                // degenerate window (lo > hi): the historical scan
                // still probes load(lo), so mirror it; proper windows
                // get the lo answer from the descent itself (it returns
                // the leftmost over-cap point), sparing a second
                // O(log H) walk on the hot path
                if lo > hi {
                    return (t_.load_at(lo) > cap).then_some(lo);
                }
                t_.first_over(lo, hi, cap)
            }
        }
    }
}

/// Push the explanation of the compulsory-part load at time `t` into
/// the scratch buffer (callers `begin_expl` first): for every item
/// whose compulsory part under the *current* domains covers `t`, the
/// literals making it so. Current-domain parts are supersets of the
/// parts any (possibly slightly stale) profile was built from, so the
/// pushed conjunction always implies at least the profile's load at
/// `t` — sound for explaining overloads from either the naive or the
/// incremental profile.
pub(crate) fn explain_profile_at(
    items: &[CumItem],
    t: i64,
    except: usize,
    ctx: &mut Ctx,
) {
    for (j, it) in items.iter().enumerate() {
        if j == except || it.demand == 0 {
            continue;
        }
        if ctx.min(it.active) != 1 {
            continue;
        }
        let ms = ctx.max(it.start);
        let me = ctx.min(it.end);
        if ms <= me && ms <= t && t <= me {
            ctx.expl_push(Lit::geq(it.active, 1));
            ctx.expl_push(Lit::leq(it.start, ms));
            ctx.expl_push(Lit::geq(it.end, me));
        }
    }
}

/// Timetable filtering of one cumulative item (`items[ii]`) against a
/// compulsory-part profile, subtracting the item's own mandatory
/// contribution. This is the single filtering implementation: the naive
/// propagator calls it with a freshly built profile, the engine with
/// its incrementally maintained one — so the two paths cannot drift
/// apart. The full item list rides along so prunings can be explained
/// by the profile's contributing items.
pub(crate) fn timetable_filter_item(
    items: &[CumItem],
    ii: usize,
    cap: i64,
    profile: &ProfileView,
    ctx: &mut Ctx,
) -> Result<(), Conflict> {
    let it = &items[ii];
    if ctx.max(it.active) == 0 {
        return Ok(());
    }
    let d = it.demand;
    if d == 0 {
        return Ok(());
    }
    // own mandatory contribution at time t (computed from bounds
    // captured before each use, to keep the borrow checker happy)
    let own = |ms: i64, me: i64, certainly_active: bool, t: i64| -> i64 {
        if certainly_active && ms <= me && ms <= t && t <= me {
            d
        } else {
            0
        }
    };
    if ctx.min(it.active) == 1 {
        // raise start lower bound while its point is overloaded
        let mut guard = 0;
        loop {
            let s = ctx.min(it.start);
            let (ms, me) = (ctx.max(it.start), ctx.min(it.end));
            if profile.load_at(s) - own(ms, me, true, s) + d <= cap {
                break;
            }
            if ctx.explaining() {
                ctx.begin_expl();
                ctx.expl_push(Lit::geq(it.active, 1));
                ctx.expl_push(Lit::geq(it.start, s));
                explain_profile_at(items, s, ii, ctx);
            }
            ctx.set_min(it.start, s + 1)?;
            // keep interval consistent: end >= start (constraint (2)
            // pairs every active cumulative item with start ≤ end)
            let s2 = ctx.min(it.start);
            if ctx.min(it.end) < s2 {
                if ctx.explaining() {
                    ctx.begin_expl();
                    ctx.expl_push(Lit::geq(it.active, 1));
                    ctx.expl_push(Lit::geq(it.start, s2));
                }
                ctx.set_min(it.end, s2)?;
            }
            guard += 1;
            if guard > 64 {
                break; // bounded effort; search completes the job
            }
        }
        // lower end upper bound while its point is overloaded
        let mut guard = 0;
        loop {
            let e = ctx.max(it.end);
            let (ms, me) = (ctx.max(it.start), ctx.min(it.end));
            if profile.load_at(e) - own(ms, me, true, e) + d <= cap {
                break;
            }
            if ctx.explaining() {
                ctx.begin_expl();
                ctx.expl_push(Lit::geq(it.active, 1));
                ctx.expl_push(Lit::leq(it.end, e));
                explain_profile_at(items, e, ii, ctx);
            }
            ctx.set_max(it.end, e - 1)?;
            let e2 = ctx.max(it.end);
            if ctx.max(it.start) > e2 {
                if ctx.explaining() {
                    ctx.begin_expl();
                    ctx.expl_push(Lit::geq(it.active, 1));
                    ctx.expl_push(Lit::leq(it.end, e2));
                }
                ctx.set_max(it.start, e2)?;
            }
            guard += 1;
            if guard > 64 {
                break;
            }
        }
    } else if ctx.is_fixed(it.start) && ctx.is_fixed(it.end) {
        // undetermined active with fixed placement: would it overload?
        let s = ctx.min(it.start);
        let e = ctx.min(it.end);
        // earliest overload point in [s, e] (a breakpoint or s itself)
        let over = profile.first_over(s, e, cap - d);
        if let Some(t) = over {
            if ctx.explaining() {
                ctx.begin_expl();
                ctx.expl_push(Lit::geq(it.start, s));
                ctx.expl_push(Lit::leq(it.start, s));
                ctx.expl_push(Lit::geq(it.end, e));
                ctx.expl_push(Lit::leq(it.end, e));
                explain_profile_at(items, t, ii, ctx);
            }
            ctx.set_max(it.active, 0)?;
        }
    }
    Ok(())
}

/// Timetable edge-finding for one cumulative item (`--filtering
/// edge-finding`): energy-based start/end filtering over the
/// compulsory-part profile, run *after* [`timetable_filter_item`].
///
/// Retention intervals have variable duration (start and end are
/// separate variables), so an item's minimal energy inside any window
/// is exactly its compulsory-part intersection — classic est/lct
/// edge-finding degenerates, and the real strengthening left is
/// window-scan filtering against the profile: the timetable raises
/// `min(start)` only through a *contiguous* overloaded prefix, while
/// the rules here jump bounds past any overloaded point the item would
/// necessarily cover.
///
/// * **Rule S** (certainly active): for `u ∈ [min(start),
///   min(min(end), max(start) − 1)]`, `start ≤ u` together with
///   `end ≥ u` (entailed: `u ≤ min(end)`) makes the item cover `u`;
///   if the profile load there (own part excluded — `u < max(start)`
///   keeps `u` outside it) plus the demand overloads, then
///   `start ≥ u + 1`. The *latest* such `u` gives the strongest bound.
/// * **Rule E** (symmetric): for `u ∈ [max(max(start), min(end) + 1),
///   max(end)]`, `end ≥ u` makes the item cover `u` (`u ≥ max(start)`
///   entails `start ≤ u`); an overload forces `end ≤ u − 1`. The
///   *earliest* such `u` is strongest.
/// * **Rule A** (optional): if activation would create a compulsory
///   part `[max(start), min(end)]` containing an overloaded point, the
///   item can never be activated — the bounds-based generalisation of
///   the fixed-placement check in [`timetable_filter_item`].
///
/// All three emit explanation conjunctions in the same `cp::Lit`
/// vocabulary as the timetable, so 1UIP learning consumes them
/// unchanged. `prunes` counts successful tightenings
/// (`SearchStats::ef_prunes`).
pub(crate) fn edge_finding_filter_item(
    items: &[CumItem],
    ii: usize,
    cap: i64,
    profile: &ProfileView,
    ctx: &mut Ctx,
    prunes: &mut u64,
) -> Result<(), Conflict> {
    let it = &items[ii];
    let d = it.demand;
    if d == 0 || ctx.max(it.active) == 0 {
        return Ok(());
    }
    if ctx.min(it.active) != 1 {
        // Rule A: would the compulsory part created by activation
        // cover an overloaded point? (Optional items are never part of
        // the profile, so no own-load subtraction is needed.)
        let ls = ctx.max(it.start);
        let ee = ctx.min(it.end);
        if ls <= ee {
            if let Some(u) = profile.first_over(ls, ee, cap - d) {
                if ctx.explaining() {
                    ctx.begin_expl();
                    ctx.expl_push(Lit::leq(it.start, u));
                    ctx.expl_push(Lit::geq(it.end, u));
                    explain_profile_at(items, u, ii, ctx);
                }
                ctx.set_max(it.active, 0)?;
                *prunes += 1;
            }
        }
        return Ok(());
    }
    // Rule S: strongest overloaded point below the compulsory zone.
    // `u ≤ max(start) − 1` keeps `u` outside the item's own part (the
    // profile load there never includes the item), `u ≤ min(end)`
    // makes `end ≥ u` entailed, `u ≥ min(start)` makes it filtering.
    let es = ctx.min(it.start);
    let hi = ctx.min(it.end).min(ctx.max(it.start) - 1);
    if es <= hi {
        if let Some(first) = profile.first_over(es, hi, cap - d) {
            // the last overloaded point in the window is the strongest
            // bound; scan down from `hi` (bounded effort, like every
            // cumulative shaving loop), falling back to the first
            // overload when the top of the window is clean
            let mut u = first;
            for k in 0..=(hi - first).min(63) {
                if profile.load_at(hi - k) + d > cap {
                    u = hi - k;
                    break;
                }
            }
            if ctx.explaining() {
                ctx.begin_expl();
                ctx.expl_push(Lit::geq(it.active, 1));
                ctx.expl_push(Lit::geq(it.end, u));
                explain_profile_at(items, u, ii, ctx);
            }
            ctx.set_min(it.start, u + 1)?;
            *prunes += 1;
        }
    }
    // Rule E: earliest overloaded point above the compulsory zone
    // (`u ≥ min(end) + 1` keeps `u` outside the own part and the new
    // bound consistent; `u ≥ max(start)` makes `start ≤ u` entailed).
    let lo = ctx.max(it.start).max(ctx.min(it.end) + 1);
    let le = ctx.max(it.end);
    if lo <= le {
        if let Some(u) = profile.first_over(lo, le, cap - d) {
            if ctx.explaining() {
                ctx.begin_expl();
                ctx.expl_push(Lit::geq(it.active, 1));
                ctx.expl_push(Lit::leq(it.start, u));
                explain_profile_at(items, u, ii, ctx);
            }
            ctx.set_max(it.end, u - 1)?;
            *prunes += 1;
        }
    }
    Ok(())
}

/// Time-table cumulative filtering over mandatory parts.
///
/// Clone-audit note: the `events` / `profile` vectors below are
/// per-pass heap allocations, deliberately kept — this from-scratch
/// build only runs on the naive reference path (`--naive`, the audit
/// replay harness, and unit tests). The engine's production path uses
/// the incremental `CumState` profile caches and never calls this.
fn prop_cumulative(items: &[CumItem], cap: i64, ctx: &mut Ctx) -> Result<(), Conflict> {
    // Mandatory part of an interval that is certainly active:
    // [start.max, end.min] if nonempty.
    // Build a compressed profile from (time, +d)/(time+1, -d) events.
    // Zero-demand items are excluded entirely (they cannot change any
    // load), keeping this profile breakpoint-identical to the engine's
    // incremental diff map, which drops zero deltas.
    let mut events: Vec<(i64, i64)> = Vec::new();
    for it in items {
        if it.demand == 0 || ctx.min(it.active) != 1 {
            continue; // no load contribution / not certainly active
        }
        let ms = ctx.max(it.start);
        let me = ctx.min(it.end);
        if ms <= me {
            events.push((ms, it.demand));
            events.push((me + 1, -it.demand));
        }
    }
    if events.is_empty() {
        return Ok(());
    }
    events.sort_unstable();
    // profile as step function: breakpoints[i] = (time, load on [time, next))
    let mut profile: Vec<(i64, i64)> = Vec::with_capacity(events.len());
    let mut load = 0i64;
    let mut i = 0;
    while i < events.len() {
        let t = events[i].0;
        while i < events.len() && events[i].0 == t {
            load += events[i].1;
            i += 1;
        }
        profile.push((t, load));
        if load > cap {
            if ctx.explaining() {
                ctx.begin_expl();
                explain_profile_at(items, t, usize::MAX, ctx);
            }
            return ctx.fail();
        }
    }
    // Filter each potentially-active interval against the profile.
    let view = ProfileView::Steps(&profile[..]);
    for ii in 0..items.len() {
        timetable_filter_item(items, ii, cap, &view, ctx)?;
    }
    Ok(())
}

/// Push why candidate `j` cannot cover any value of `start`'s current
/// domain: its activation is off, its window starts too late, or it
/// ends too early (each case referencing the target-side bound that
/// closes the window). Used to explain every `Cover` inference.
fn push_cover_exclusion(
    start: VarId,
    candidates: &[(VarId, VarId, VarId)],
    j: usize,
    ctx: &mut Ctx,
) {
    let (a, s, e) = candidates[j];
    if ctx.max(a) == 0 {
        ctx.expl_push(Lit::leq(a, 0));
    } else if ctx.min(s) + 1 > ctx.max(start) {
        let ls = Lit::geq(s, ctx.min(s));
        let lt = Lit::leq(start, ctx.max(start));
        ctx.expl_push(ls);
        ctx.expl_push(lt);
    } else {
        let le = Lit::leq(e, ctx.max(e));
        let lt = Lit::geq(start, ctx.min(start));
        ctx.expl_push(le);
        ctx.expl_push(lt);
    }
}

/// Explain a window-bound tightening of a covered start: the target is
/// active, every impossible candidate is excluded, and each possible
/// candidate's own window bound (`is_lo`: its start's min; else its
/// end's max) caps what it could cover.
fn explain_cover_window(
    active: VarId,
    start: VarId,
    candidates: &[(VarId, VarId, VarId)],
    possible: &[u32],
    is_lo: bool,
    ctx: &mut Ctx,
) {
    ctx.begin_expl();
    ctx.expl_push(Lit::geq(active, 1));
    let mut p = 0;
    for j in 0..candidates.len() {
        if p < possible.len() && possible[p] as usize == j {
            p += 1;
            let (_, s, e) = candidates[j];
            let l = if is_lo {
                Lit::geq(s, ctx.min(s))
            } else {
                Lit::leq(e, ctx.max(e))
            };
            ctx.expl_push(l);
        } else {
            push_cover_exclusion(start, candidates, j, ctx);
        }
    }
}

/// Reservoir-style precedence cover. `possible` is a caller-owned
/// scratch buffer for the possible-candidate indices (cleared here),
/// so the hottest cheap propagator performs no per-pass allocation.
fn prop_cover(
    active: VarId,
    start: VarId,
    candidates: &[(VarId, VarId, VarId)],
    possible: &mut Vec<u32>,
    ctx: &mut Ctx,
) -> Result<(), Conflict> {
    if ctx.max(active) == 0 {
        return Ok(());
    }
    let t_min = ctx.min(start);
    let t_max = ctx.max(start);
    // candidate j can possibly cover some t in [t_min, t_max] iff
    // s_j.min + 1 <= t_max  and  e_j.max >= t_min  and a_j can be 1.
    possible.clear();
    for (j, &(a, s, e)) in candidates.iter().enumerate() {
        if ctx.max(a) == 0 {
            continue;
        }
        if ctx.min(s) + 1 <= t_max && ctx.max(e) >= t_min {
            possible.push(j as u32);
        }
    }
    if possible.is_empty() {
        if ctx.explaining() {
            ctx.begin_expl();
            for j in 0..candidates.len() {
                push_cover_exclusion(start, candidates, j, ctx);
            }
            if ctx.min(active) == 1 {
                ctx.expl_push(Lit::geq(active, 1));
            }
        }
        if ctx.min(active) == 1 {
            return ctx.fail();
        }
        return ctx.set_max(active, 0);
    }
    if ctx.min(active) != 1 {
        return Ok(()); // target not (yet) active: nothing to enforce
    }
    // Bounds on the covered start: it must fit inside the union of
    // candidate windows. Explanation: the target is active, every
    // candidate outside `possible` is excluded, and each possible
    // candidate's own window bound caps what it could cover.
    let (Some(lo), Some(hi)) = (
        possible.iter().map(|&j| ctx.min(candidates[j as usize].1) + 1).min(),
        possible.iter().map(|&j| ctx.max(candidates[j as usize].2)).max(),
    ) else {
        return Ok(()); // unreachable: `possible` is non-empty past the check above
    };
    if lo > ctx.min(start) {
        if ctx.explaining() {
            explain_cover_window(active, start, candidates, possible, true, ctx);
        }
        ctx.set_min(start, lo)?;
    }
    if hi < ctx.max(start) {
        if ctx.explaining() {
            explain_cover_window(active, start, candidates, possible, false, ctx);
        }
        ctx.set_max(start, hi)?;
    }
    if possible.len() == 1 {
        let only = possible[0] as usize;
        let (a, s, e) = candidates[only];
        // base reason: the target is active and every other candidate
        // is excluded → only this candidate can cover the start
        let explain_forced = |extra: Option<Lit>, ctx: &mut Ctx| {
            ctx.begin_expl();
            ctx.expl_push(Lit::geq(active, 1));
            for j in 0..candidates.len() {
                if j != only {
                    push_cover_exclusion(start, candidates, j, ctx);
                }
            }
            if let Some(l) = extra {
                ctx.expl_push(l);
            }
        };
        if ctx.explaining() {
            explain_forced(None, ctx);
        }
        ctx.set_min(a, 1)?;
        // s + 1 <= start <= e
        if ctx.explaining() {
            let l = Lit::leq(start, ctx.max(start));
            explain_forced(Some(l), ctx);
        }
        ctx.set_max(s, ctx.max(start) - 1)?;
        if ctx.explaining() {
            let l = Lit::geq(start, ctx.min(start));
            explain_forced(Some(l), ctx);
        }
        ctx.set_min(e, ctx.min(start))?;
        if ctx.explaining() {
            let l = Lit::geq(s, ctx.min(s));
            explain_forced(Some(l), ctx);
        }
        ctx.set_min(start, ctx.min(s) + 1)?;
        if ctx.explaining() {
            let l = Lit::leq(e, ctx.max(e));
            explain_forced(Some(l), ctx);
        }
        ctx.set_max(start, ctx.max(e))?;
    }
    Ok(())
}

fn prop_all_different(vars: &[VarId], ctx: &mut Ctx) -> Result<(), Conflict> {
    // Fixed-value propagation with bound shaving (sufficient for the
    // unstaged model's small instances; the staged model doesn't use it).
    // Explanations: every inference follows from `x` being fixed at `v`
    // plus the shaved bound of `y` touching `v`.
    for (i, &x) in vars.iter().enumerate() {
        if !ctx.is_fixed(x) {
            continue;
        }
        let v = ctx.min(x);
        for (j, &y) in vars.iter().enumerate() {
            if i == j {
                continue;
            }
            if ctx.is_fixed(y) {
                if ctx.min(y) == v {
                    if ctx.explaining() {
                        ctx.begin_expl();
                        ctx.expl_push(Lit::geq(x, v));
                        ctx.expl_push(Lit::leq(x, v));
                        ctx.expl_push(Lit::geq(y, v));
                        ctx.expl_push(Lit::leq(y, v));
                    }
                    return ctx.fail();
                }
            } else {
                if ctx.min(y) == v {
                    if ctx.explaining() {
                        ctx.begin_expl();
                        ctx.expl_push(Lit::geq(x, v));
                        ctx.expl_push(Lit::leq(x, v));
                        ctx.expl_push(Lit::geq(y, v));
                    }
                    ctx.set_min(y, v + 1)?;
                }
                if ctx.max(y) == v {
                    if ctx.explaining() {
                        ctx.begin_expl();
                        ctx.expl_push(Lit::geq(x, v));
                        ctx.expl_push(Lit::leq(x, v));
                        ctx.expl_push(Lit::leq(y, v));
                    }
                    ctx.set_max(y, v - 1)?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::domain::Domain;
    use std::sync::Arc;

    fn mk(doms: &[(i64, i64)]) -> DomStore {
        let doms: Vec<Domain> = doms
            .iter()
            .map(|&(lo, hi)| Domain::new(Arc::new((lo..=hi).collect())))
            .collect();
        let mut store = DomStore::default();
        store.load_from(&doms);
        store
    }

    fn run(p: &Propagator, doms: &mut DomStore) -> Result<(), Conflict> {
        let mut trail = Vec::new();
        let mut changed = Vec::new();
        let mut expl = ExplState::new(doms.len(), false);
        let mut ctx = Ctx { doms, trail: &mut trail, changed: &mut changed, expl: &mut expl };
        p.propagate(&mut ctx)
    }

    /// Single-target cover (the pre-compaction shape) for the tests.
    fn cover1(active: VarId, start: VarId, candidates: Vec<(VarId, VarId, VarId)>) -> Propagator {
        Propagator::Cover {
            targets: Arc::from(vec![(active, start)]),
            candidates: Arc::from(candidates),
        }
    }

    #[test]
    fn linear_le_filters_upper_bounds() {
        // 2x + 3y <= 10, x,y in [0,5] → x <= 5, y <= 3
        let mut d = mk(&[(0, 5), (0, 5)]);
        let p = Propagator::LinearLe {
            terms: vec![(2, VarId(0)), (3, VarId(1))],
            rhs: 10,
        };
        run(&p, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.max(VarId(0)), 5);
        assert_eq!(d.max(VarId(1)), 3);
    }

    #[test]
    fn linear_le_conflict() {
        let mut d = mk(&[(4, 5)]);
        let p = Propagator::LinearLe { terms: vec![(1, VarId(0))], rhs: 3 };
        assert!(run(&p, &mut d).is_err());
    }

    #[test]
    fn linear_le_negative_coeff_raises_lb() {
        // -x <= -3  →  x >= 3
        let mut d = mk(&[(0, 5)]);
        let p = Propagator::LinearLe { terms: vec![(-1, VarId(0))], rhs: -3 };
        run(&p, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.min(VarId(0)), 3);
    }

    #[test]
    fn le_offset_both_directions() {
        // x + 2 <= y, x in [0,9], y in [1, 6] → x <= 4, y >= 2
        let mut d = mk(&[(0, 9), (1, 6)]);
        let p = Propagator::LeOffset { b: None, x: VarId(0), c: 2, y: VarId(1) };
        run(&p, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.max(VarId(0)), 4);
        assert_eq!(d.min(VarId(1)), 2);
    }

    #[test]
    fn cond_le_offset_forces_guard_false() {
        // b → x + 5 <= y with x>=4, y<=6 impossible → b = 0
        let mut d = mk(&[(0, 1), (4, 9), (0, 6)]);
        let p = Propagator::LeOffset { b: Some(VarId(0)), x: VarId(1), c: 5, y: VarId(2) };
        run(&p, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.max(VarId(0)), 0);
    }

    #[test]
    fn cumulative_mandatory_conflict() {
        // two fixed active intervals [2,4] and [3,5], demands 2+2 > cap 3
        let mut d = mk(&[(1, 1), (2, 2), (4, 4), (1, 1), (3, 3), (5, 5)]);
        let p = Propagator::Cumulative {
            items: vec![
                CumItem { active: VarId(0), start: VarId(1), end: VarId(2), demand: 2 },
                CumItem { active: VarId(3), start: VarId(4), end: VarId(5), demand: 2 },
            ],
            cap: 3,
        };
        assert!(run(&p, &mut d).is_err());
    }

    #[test]
    fn cumulative_pushes_start_past_busy_region() {
        // fixed interval [0,3] demand 2, cap 3; second interval demand 2
        // with start in [0,6], end fixed 8 → start must be >= 4
        let mut d = mk(&[(1, 1), (0, 0), (3, 3), (1, 1), (0, 6), (8, 8)]);
        let p = Propagator::Cumulative {
            items: vec![
                CumItem { active: VarId(0), start: VarId(1), end: VarId(2), demand: 2 },
                CumItem { active: VarId(3), start: VarId(4), end: VarId(5), demand: 2 },
            ],
            cap: 3,
        };
        run(&p, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.min(VarId(4)), 4);
    }

    #[test]
    fn cumulative_disables_overloading_optional() {
        // busy [0,5] at demand 3 (cap 3); optional fixed at [2,4] demand 1
        // → active forced 0
        let mut d = mk(&[(1, 1), (0, 0), (5, 5), (0, 1), (2, 2), (4, 4)]);
        let p = Propagator::Cumulative {
            items: vec![
                CumItem { active: VarId(0), start: VarId(1), end: VarId(2), demand: 3 },
                CumItem { active: VarId(3), start: VarId(4), end: VarId(5), demand: 1 },
            ],
            cap: 3,
        };
        run(&p, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.max(VarId(3)), 0);
    }

    #[test]
    fn cover_conflict_when_no_candidate() {
        // target active, start=5; candidate interval ends at 3 → conflict
        let mut d = mk(&[(1, 1), (5, 5), (1, 1), (0, 0), (3, 3)]);
        let p = cover1(VarId(0), VarId(1), vec![(VarId(2), VarId(3), VarId(4))]);
        assert!(run(&p, &mut d).is_err());
    }

    #[test]
    fn cover_single_candidate_forces_activation_and_extends_end() {
        // target start=5, candidate a in {0,1}, s=2, e in [2,9]
        // → a=1, e >= 5
        let mut d = mk(&[(1, 1), (5, 5), (0, 1), (2, 2), (2, 9)]);
        let p = cover1(VarId(0), VarId(1), vec![(VarId(2), VarId(3), VarId(4))]);
        run(&p, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.min(VarId(2)), 1);
        assert_eq!(d.min(VarId(4)), 5);
    }

    #[test]
    fn cover_inactive_target_is_vacuous() {
        let mut d = mk(&[(0, 0), (5, 5), (0, 1), (2, 2), (2, 3)]);
        let p = cover1(VarId(0), VarId(1), vec![(VarId(2), VarId(3), VarId(4))]);
        run(&p, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.min(VarId(2)), 0); // untouched
    }

    #[test]
    fn all_different_shaves_bounds() {
        let mut d = mk(&[(3, 3), (3, 5), (0, 3)]);
        let p = Propagator::AllDifferent { vars: vec![VarId(0), VarId(1), VarId(2)] };
        run(&p, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.min(VarId(1)), 4);
        assert_eq!(d.max(VarId(2)), 2);
    }

    #[test]
    fn all_different_conflict() {
        let mut d = mk(&[(3, 3), (3, 3)]);
        let p = Propagator::AllDifferent { vars: vec![VarId(0), VarId(1)] };
        assert!(run(&p, &mut d).is_err());
    }

    #[test]
    fn satisfaction_checks() {
        let lin = Propagator::LinearLe { terms: vec![(2, VarId(0)), (1, VarId(1))], rhs: 5 };
        assert!(lin.is_satisfied(&[2, 1]));
        assert!(!lin.is_satisfied(&[2, 2]));
        let cum = Propagator::Cumulative {
            items: vec![
                CumItem { active: VarId(0), start: VarId(1), end: VarId(2), demand: 2 },
                CumItem { active: VarId(3), start: VarId(4), end: VarId(5), demand: 2 },
            ],
            cap: 3,
        };
        // overlapping actives exceed cap
        assert!(!cum.is_satisfied(&[1, 0, 4, 1, 2, 6]));
        // disjoint ok
        assert!(cum.is_satisfied(&[1, 0, 1, 1, 2, 6]));
        // inactive ignored
        assert!(cum.is_satisfied(&[1, 0, 4, 0, 2, 6]));
        let cov = cover1(VarId(0), VarId(1), vec![(VarId(2), VarId(3), VarId(4))]);
        assert!(cov.is_satisfied(&[1, 5, 1, 2, 7]));
        assert!(!cov.is_satisfied(&[1, 5, 1, 5, 7])); // s+1 <= t violated
        assert!(!cov.is_satisfied(&[1, 5, 0, 2, 7])); // candidate inactive
        assert!(cov.is_satisfied(&[0, 5, 0, 2, 7])); // target inactive
    }

    #[test]
    fn multi_target_cover_filters_each_target() {
        // two targets over one candidate (a fixed 1, s=2, e in [2,9]):
        // both targets active with starts 5 and 7 → e >= 7
        let mut d = mk(&[(1, 1), (5, 5), (1, 1), (7, 7), (1, 1), (2, 2), (2, 9)]);
        let p = Propagator::Cover {
            targets: Arc::from(vec![(VarId(0), VarId(1)), (VarId(2), VarId(3))]),
            candidates: Arc::from(vec![(VarId(4), VarId(5), VarId(6))]),
        };
        run(&p, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.min(VarId(6)), 7);
        // satisfaction: both targets must be covered
        assert!(p.is_satisfied(&[1, 5, 1, 7, 1, 2, 9]));
        assert!(!p.is_satisfied(&[1, 5, 1, 7, 1, 2, 6]), "second target uncovered");
        assert!(p.is_satisfied(&[1, 5, 0, 7, 1, 2, 6]), "inactive target is vacuous");
    }
}
