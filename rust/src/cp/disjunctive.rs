//! Disjunctive (unary-resource) propagation over serialized intervals.
//!
//! The presolve detects "heavy cliques" in each cumulative constraint:
//! items whose demands pairwise exceed the capacity
//! (`demand_i + demand_j > cap` for every pair, guaranteed by the
//! per-item test `2·demand > cap`). Any two such items can never
//! overlap in time — in the Moccasin model these are the large tensors
//! of a tight-budget regime, whose retention intervals effectively
//! serialize. The cumulative timetable reasons about them only through
//! compulsory parts, which is weak while the intervals are loose; the
//! pairwise rules here fire as soon as *bounds* make an order
//! impossible:
//!
//! * **Order conflict.** Both items certainly active and neither can
//!   precede the other (`min(end_i) + 1 > max(start_j)` both ways) —
//!   fail.
//! * **Forced order.** Both certainly active and only one order is
//!   possible: the follower starts after the leader's earliest end
//!   (`start_j ≥ min(end_i) + 1`) and the leader ends before the
//!   follower's latest start (`end_i ≤ max(start_j) − 1`).
//! * **Deactivation.** One item certainly active, the other optional,
//!   and no order possible: the optional item can never be activated.
//!
//! Every pruning emits a `cp::Lit` explanation conjunction (the
//! activity literals plus the four interval bounds making the excluded
//! order impossible), so 1UIP learning applies to disjunctive filtering
//! exactly as it does to the timetable. The rules are deliberately
//! bounds-based (no edge-finding over the clique): with the tiny clique
//! sizes detection yields, the O(h²) pairwise pass is already cheap,
//! and exactness never depends on strength — solutions are verified.

use super::domain::{Lit, VarId};
use super::propagators::{Conflict, Ctx};

/// One optional interval on a unary (serialized) resource. Demands are
/// deliberately absent: membership in the clique already encodes
/// "pairwise over capacity", which is all the propagation uses.
#[derive(Debug, Clone)]
pub struct DisjItem {
    /// Boolean: the interval exists.
    pub active: VarId,
    /// First event covered by the interval.
    pub start: VarId,
    /// Last event covered by the interval (inclusive).
    pub end: VarId,
}

/// Pairwise disjunctive filtering over `items` (see module docs).
/// `prunes` counts successful tightenings / deactivations
/// (`SearchStats::disj_prunes`).
pub(crate) fn prop_disjunctive(
    items: &[DisjItem],
    ctx: &mut Ctx,
    prunes: &mut u64,
) -> Result<(), Conflict> {
    for i in 0..items.len() {
        if ctx.max(items[i].active) == 0 {
            continue;
        }
        for j in i + 1..items.len() {
            if ctx.max(items[j].active) == 0 {
                continue;
            }
            prop_pair(items, i, j, ctx, prunes)?;
        }
    }
    Ok(())
}

/// Push the four bound literals making "j before i" impossible
/// (`min(end_j) + 1 > max(start_i)`) plus both current-truth interval
/// bounds the forced-order bounds derive through. All literals are
/// currently true, as explanations require.
fn push_order_impossible(a: &DisjItem, b: &DisjItem, ctx: &mut Ctx) {
    // "b before a" impossible: end_b ≥ min(end_b) and
    // start_a ≤ max(start_a) with min(end_b) + 1 > max(start_a)
    let le = Lit::geq(b.end, ctx.min(b.end));
    let ls = Lit::leq(a.start, ctx.max(a.start));
    ctx.expl_push(le);
    ctx.expl_push(ls);
}

/// One ordered pair: apply the three rules to `(items[i], items[j])`.
fn prop_pair(
    items: &[DisjItem],
    i: usize,
    j: usize,
    ctx: &mut Ctx,
    prunes: &mut u64,
) -> Result<(), Conflict> {
    let (a, b) = (&items[i], &items[j]);
    // "i before j" requires end_i < start_j, possible iff
    // min(end_i) + 1 ≤ max(start_j); symmetrically for "j before i".
    let ij_possible = ctx.min(a.end) + 1 <= ctx.max(b.start);
    let ji_possible = ctx.min(b.end) + 1 <= ctx.max(a.start);
    if ij_possible && ji_possible {
        return Ok(()); // both orders open: nothing to conclude
    }
    let cert_i = ctx.min(a.active) == 1;
    let cert_j = ctx.min(b.active) == 1;
    if cert_i && cert_j {
        if !ij_possible && !ji_possible {
            // overlap is forbidden and neither order fits — conflict
            if ctx.explaining() {
                ctx.begin_expl();
                ctx.expl_push(Lit::geq(a.active, 1));
                ctx.expl_push(Lit::geq(b.active, 1));
                push_order_impossible(b, a, ctx); // "i before j" impossible
                push_order_impossible(a, b, ctx); // "j before i" impossible
            }
            return ctx.fail();
        }
        // exactly one order open: orient the pair (leader, follower)
        let (leader, follower) = if ij_possible { (a, b) } else { (b, a) };
        // follower starts after the leader's earliest end
        let lb = ctx.min(leader.end) + 1;
        if ctx.min(follower.start) < lb {
            if ctx.explaining() {
                ctx.begin_expl();
                ctx.expl_push(Lit::geq(a.active, 1));
                ctx.expl_push(Lit::geq(b.active, 1));
                ctx.expl_push(Lit::geq(leader.end, ctx.min(leader.end)));
                push_order_impossible(leader, follower, ctx);
            }
            ctx.set_min(follower.start, lb)?;
            *prunes += 1;
        }
        // leader ends before the follower's latest start
        let ub = ctx.max(follower.start) - 1;
        if ctx.max(leader.end) > ub {
            if ctx.explaining() {
                ctx.begin_expl();
                ctx.expl_push(Lit::geq(a.active, 1));
                ctx.expl_push(Lit::geq(b.active, 1));
                ctx.expl_push(Lit::leq(follower.start, ctx.max(follower.start)));
                push_order_impossible(leader, follower, ctx);
            }
            ctx.set_max(leader.end, ub)?;
            *prunes += 1;
        }
        return Ok(());
    }
    if !ij_possible && !ji_possible && (cert_i || cert_j) {
        // one certain, one optional, no order fits: the optional item
        // can never be activated alongside the certain one
        let (certain, optional) = if cert_i { (a, b) } else { (b, a) };
        if ctx.explaining() {
            ctx.begin_expl();
            ctx.expl_push(Lit::geq(certain.active, 1));
            push_order_impossible(b, a, ctx);
            push_order_impossible(a, b, ctx);
        }
        ctx.set_max(optional.active, 0)?;
        *prunes += 1;
    }
    Ok(())
}

/// Full-assignment check: active intervals are pairwise disjoint.
pub(crate) fn disj_satisfied(items: &[DisjItem], a: &[i64]) -> bool {
    let val = |v: VarId| a[v.0 as usize];
    for i in 0..items.len() {
        if val(items[i].active) != 1 {
            continue;
        }
        for j in i + 1..items.len() {
            if val(items[j].active) != 1 {
                continue;
            }
            let before = val(items[i].end) < val(items[j].start);
            let after = val(items[j].end) < val(items[i].start);
            if !before && !after {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::super::domain::{DomStore, Domain};
    use super::super::propagators::ExplState;
    use super::*;
    use std::sync::Arc;

    fn mk(doms: &[(i64, i64)]) -> DomStore {
        let doms: Vec<Domain> = doms
            .iter()
            .map(|&(lo, hi)| Domain::new(Arc::new((lo..=hi).collect())))
            .collect();
        let mut store = DomStore::default();
        store.load_from(&doms);
        store
    }

    fn item(base: u32) -> DisjItem {
        DisjItem { active: VarId(base), start: VarId(base + 1), end: VarId(base + 2) }
    }

    fn run(items: &[DisjItem], doms: &mut DomStore) -> Result<u64, Conflict> {
        let mut trail = Vec::new();
        let mut changed = Vec::new();
        let mut expl = ExplState::new(doms.len(), false);
        let mut ctx = Ctx { doms, trail: &mut trail, changed: &mut changed, expl: &mut expl };
        let mut prunes = 0;
        prop_disjunctive(items, &mut ctx, &mut prunes)?;
        Ok(prunes)
    }

    #[test]
    fn forced_order_tightens_both_sides() {
        // i: active, start [0,2], end [3,4]; j: active, start [1,8],
        // end [9,9]. "j before i" needs min(end_j)+1 = 10 ≤ max(start_i)
        // = 2: impossible → i leads: start_j ≥ 4, end_i ≤ 7.
        let mut d = mk(&[(1, 1), (0, 2), (3, 4), (1, 1), (1, 8), (9, 9)]);
        let items = [item(0), item(3)];
        let prunes = run(&items, &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.min(VarId(4)), 4, "follower start raised past leader's earliest end");
        assert_eq!(d.max(VarId(2)), 4, "leader end already below follower's latest start");
        assert_eq!(prunes, 1);
    }

    #[test]
    fn no_order_conflicts_when_both_certain() {
        // both fixed overlapping: [2,6] and [4,8] → neither order fits
        let mut d = mk(&[(1, 1), (2, 2), (6, 6), (1, 1), (4, 4), (8, 8)]);
        assert!(run(&[item(0), item(3)], &mut d).is_err());
    }

    #[test]
    fn no_order_deactivates_optional() {
        // same geometry but the second item is optional → active_j = 0
        let mut d = mk(&[(1, 1), (2, 2), (6, 6), (0, 1), (4, 4), (8, 8)]);
        let prunes = run(&[item(0), item(3)], &mut d).map_err(|_| ()).unwrap();
        assert_eq!(d.max(VarId(3)), 0);
        assert_eq!(prunes, 1);
    }

    #[test]
    fn open_orders_and_optional_pairs_are_left_alone() {
        // both orders possible → no filtering even when certain
        let mut d = mk(&[(1, 1), (0, 9), (0, 9), (1, 1), (0, 9), (0, 9)]);
        assert_eq!(run(&[item(0), item(3)], &mut d).unwrap_or(99), 0);
        // both optional → no filtering regardless of geometry
        let mut d = mk(&[(0, 1), (2, 2), (6, 6), (0, 1), (4, 4), (8, 8)]);
        assert_eq!(run(&[item(0), item(3)], &mut d).unwrap_or(99), 0);
    }

    #[test]
    fn satisfaction_is_pairwise_disjointness() {
        let items = [item(0), item(3)];
        assert!(disj_satisfied(&items, &[1, 0, 1, 1, 2, 6]));
        assert!(!disj_satisfied(&items, &[1, 0, 4, 1, 2, 6]));
        assert!(disj_satisfied(&items, &[1, 0, 4, 0, 2, 6]), "inactive ignored");
        assert!(disj_satisfied(&items, &[1, 5, 9, 1, 0, 4]), "order is symmetric");
    }
}
