//! Hot-path micro-benchmarks (custom harness): sequence evaluation,
//! Phase-1 planning, and the CP kernel's branch-and-bound node
//! throughput — the inner loops of Phase 1/LNS/exact solves.

use moccasin::cp::Solver;
use moccasin::generators::random_layered;
use moccasin::graph::{topological_order, Evaluator};
use moccasin::moccasin::StagedModel;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..3 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<40} {:>12.1} us/iter", per * 1e6);
}

fn main() {
    println!("== hot-path micro benches ==");
    for (n, m) in [(100usize, 236usize), (250, 944), (1000, 5875)] {
        let g = random_layered(&format!("rl{n}"), n, m, n as u64);
        let order = topological_order(&g).unwrap();
        let mut ev = Evaluator::new(&g);
        bench(&format!("eval_sequence n={n}"), 2000, || {
            let e = ev.eval(&order).unwrap();
            std::hint::black_box(e.peak_mem);
        });
        bench(&format!("eval_profile n={n}"), 1000, || {
            let e = ev.eval_profile(&order).unwrap();
            std::hint::black_box(e.1.len());
        });
    }
    // Phase-1 planner end to end on a mid graph
    let g = random_layered("rl250", 250, 944, 2);
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    bench("phase1_greedy n=250 @90%", 5, || {
        let s = moccasin::moccasin::greedy::greedy_remat(&g, &order, (peak as f64 * 0.9) as u64);
        std::hint::black_box(s.map(|x| x.eval.duration));
    });

    // CP kernel: B&B node throughput on a staged model, node-capped so
    // the measurement is trajectory-independent across engine changes
    // (filtering is equivalence-tested, so the visited tree is fixed)
    let g = random_layered("rl60", 60, 150, 7);
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    let budget = (peak as f64 * 0.85) as u64;
    let sm = StagedModel::build(&g, &order, budget, &vec![2; g.n()]);
    let (bo, guards) = sm.branch_order();
    let mut last_nodes = 0;
    bench("cp_search 20k nodes n=60 @85%", 3, || {
        let solver =
            Solver { node_limit: 20_000, guards: Some(guards.clone()), ..Default::default() };
        let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
        last_nodes = r.stats.nodes;
        std::hint::black_box((r.stats.nodes, r.stats.propagations));
    });
    println!("  (cp_search visited {last_nodes} nodes per run)");
}
