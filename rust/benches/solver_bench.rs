//! Solver benchmarks (custom harness): quick versions of the paper's
//! experiment grid — one row per table/figure family — plus the
//! machine-readable kernel bench that writes `BENCH_solver.json`
//! (nodes/sec, propagations/sec, wall time per Figure-5-style
//! instance). Full runs: `moccasin bench all --time-limit 60`.
//!
//! `cargo bench --bench solver_bench -- --smoke` runs only the JSON
//! kernel bench with a short limit — the CI perf-tracking step.

use moccasin::bench;
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("== solver bench (smoke: kernel counters only) ==");
        bench::bench_solver_json(Duration::from_secs(3), true);
        return;
    }
    let tl = Duration::from_secs(8);
    println!("== solver bench (quick; full grid via `moccasin bench all`) ==");
    bench::table1();
    bench::ablation_topo();
    bench::fig1(tl);
    bench::fig6(tl, true);
    bench::ablation_c(tl);
    bench::bench_solver_json(tl, false);
}
