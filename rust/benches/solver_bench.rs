//! Solver benchmarks (custom harness): quick versions of the paper's
//! experiment grid — one row per table/figure family. Full runs:
//! `moccasin bench all --time-limit 60`.

use moccasin::bench;
use std::time::Duration;

fn main() {
    let tl = Duration::from_secs(8);
    println!("== solver bench (quick; full grid via `moccasin bench all`) ==");
    bench::table1();
    bench::ablation_topo();
    bench::fig1(tl);
    bench::fig6(tl, true);
    bench::ablation_c(tl);
}
