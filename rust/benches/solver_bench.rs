//! Solver benchmarks (custom harness): quick versions of the paper's
//! experiment grid — one row per table/figure family — plus the
//! machine-readable kernel bench that writes `BENCH_solver.json`
//! (nodes/sec, propagations/sec, wall time and search-strategy
//! counters per Figure-5-style instance). Full runs:
//! `moccasin bench all --time-limit 60`.
//!
//! `cargo bench --bench solver_bench -- --smoke` runs only the JSON
//! kernel bench with a short limit — the CI perf-tracking step. Pass
//! `--search chronological|learned` to A/B the two search strategies
//! (CI runs the smoke once per strategy and uploads both JSONs).

use moccasin::bench;
use moccasin::cp::SearchStrategy;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let search = args
        .iter()
        .position(|a| a == "--search")
        .and_then(|i| args.get(i + 1))
        .map(|name| {
            SearchStrategy::parse(name).unwrap_or_else(|| {
                eprintln!("unknown search strategy {name} (use chronological|learned)");
                std::process::exit(2);
            })
        })
        .unwrap_or_default();
    // bench targets report failures (e.g. an unknown graph name) as
    // errors rather than aborting the process
    let run = |r: moccasin::util::Result<()>| {
        if let Err(e) = r {
            eprintln!("bench failed: {e}");
            std::process::exit(1);
        }
    };
    if smoke {
        println!("== solver bench (smoke: kernel counters only) ==");
        run(bench::bench_solver_json(Duration::from_secs(3), true, search));
        return;
    }
    let tl = Duration::from_secs(8);
    println!("== solver bench (quick; full grid via `moccasin bench all`) ==");
    bench::table1();
    run(bench::ablation_topo());
    run(bench::fig1(tl));
    run(bench::fig6(tl, true));
    run(bench::ablation_c(tl));
    run(bench::bench_solver_json(tl, false, search));
}
